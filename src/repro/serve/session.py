"""ServeSession — request batching across concurrent clients.

A `PartitionService` is lock-safe but sequential; this module gives it the
front door: clients submit verb requests from any thread, a bounded queue
feeds one worker thread that owns the service, and consecutive queued
lookups are coalesced into a single label gather (one fancy-index instead
of q small ones — the serving-path analogue of the drivers' δ-batching).

Lifecycle follows the PR 7/8 pipeline discipline (core/pipeline.py):

* the queue is bounded, so a slow service back-pressures submitters
  instead of growing an unbounded backlog;
* the worker polls with a short timeout and honors a stop event, so
  shutdown never hinges on a sentinel surviving a full queue;
* `close()` joins with a timeout on every exit path and raises loudly if
  the worker is wedged or died — with the worker's root-cause exception
  chained, never a bare "thread stopped";
* per-request errors (bad node id, absent edge) fail *that request's*
  future and the worker keeps serving; only infrastructure failures kill
  the loop, and then every pending future is failed with the root cause.

Use as a context manager::

    with ServeSession(service) as sess:
        labels = sess.lookup([0, 1, 2])
        sess.update(insert_edges=[(0, 9)])
        sess.refine()
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future

import numpy as np

from repro.serve.service import PartitionService

_POLL_S = 0.05
_JOIN_TIMEOUT_S = 5.0

_VERBS = ("lookup", "update", "refine")

_POISON = object()


@dataclasses.dataclass
class _Request:
    kind: str
    payload: object
    future: Future


class ServeSession:
    """Bounded-queue, single-worker front door for a `PartitionService`.

    `submit_*` methods enqueue and return a `concurrent.futures.Future`;
    the blocking `lookup`/`update`/`refine` wrappers wait for the result.
    Requests execute in strict FIFO submission order (coalesced lookups
    preserve per-request result boundaries), so a given request sequence is
    as deterministic as the service itself.
    """

    def __init__(
        self,
        service: PartitionService,
        *,
        queue_depth: int = 256,
        coalesce_lookups: bool = True,
        name: str = "serve-worker",
    ):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.service = service
        self._q: "queue.Queue[object]" = queue.Queue(maxsize=queue_depth)
        self._coalesce = bool(coalesce_lookups)
        self._stop = threading.Event()
        self._closed = False
        self._error: "BaseException | None" = None
        self.stats = {"requests": 0, "lookups": 0, "updates": 0,
                      "refines": 0, "coalesced_lookups": 0}
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- clients
    def _submit(self, kind: str, payload) -> Future:
        if self._closed:
            raise RuntimeError("ServeSession is closed")
        if self._error is not None:
            raise RuntimeError(
                "ServeSession worker died; no further requests accepted"
            ) from self._error
        fut: Future = Future()
        try:
            self._q.put(_Request(kind, payload, fut), timeout=_JOIN_TIMEOUT_S)
        except queue.Full as e:
            if self._error is not None:
                raise RuntimeError(
                    "ServeSession worker died with a full queue"
                ) from self._error
            raise RuntimeError(
                f"ServeSession queue stayed full for {_JOIN_TIMEOUT_S:.0f}s "
                "— the service is not keeping up; raise queue_depth or slow "
                "the submitters"
            ) from e
        return fut

    def submit_lookup(self, nodes) -> Future:
        return self._submit("lookup", np.asarray(nodes, dtype=np.int64).ravel())

    def submit_update(self, *, add_nodes=None, insert_edges=None,
                      delete_edges=None) -> Future:
        return self._submit("update", {
            "add_nodes": add_nodes, "insert_edges": insert_edges,
            "delete_edges": delete_edges,
        })

    def submit_refine(self, budget: "int | None" = None) -> Future:
        return self._submit("refine", budget)

    def lookup(self, nodes) -> np.ndarray:
        return self.submit_lookup(nodes).result()

    def update(self, **kwargs) -> dict:
        return self.submit_update(**kwargs).result()

    def refine(self, budget: "int | None" = None) -> dict:
        return self.submit_refine(budget).result()

    # -------------------------------------------------------------- worker
    def _next(self):
        """Blocking dequeue honoring poison and the stop event."""
        while True:
            try:
                req = self._q.get(timeout=_POLL_S)
            except queue.Empty:
                if self._stop.is_set():
                    return None
                continue
            if req is _POISON:
                return None
            return req

    def _execute(self, req: _Request) -> None:
        try:
            if req.kind == "lookup":
                out = self.service.lookup(req.payload)
            elif req.kind == "update":
                out = self.service.update(**req.payload)
            elif req.kind == "refine":
                out = self.service.refine(req.payload)
            else:  # pragma: no cover - submit() only enqueues known verbs
                raise RuntimeError(f"unknown verb {req.kind!r}")
            self.stats["requests"] += 1
            self.stats[req.kind + "s"] += 1
            req.future.set_result(out)
        except Exception as e:  # per-request failure: fail it, keep serving
            self.stats["requests"] += 1
            req.future.set_exception(e)

    def _lookup_batch(self, batch: "list[_Request]") -> None:
        """One coalesced gather for consecutive queued lookups; on any
        error, fall back to per-request execution so the failure lands on
        the offending request only."""
        if len(batch) == 1:
            self._execute(batch[0])
            return
        try:
            sizes = [r.payload.shape[0] for r in batch]
            flat = self.service.lookup(np.concatenate([r.payload for r in batch]))
            off = 0
            for r, sz in zip(batch, sizes):
                r.future.set_result(flat[off:off + sz])
                off += sz
            self.stats["requests"] += len(batch)
            self.stats["lookups"] += len(batch)
            self.stats["coalesced_lookups"] += len(batch) - 1
        except Exception:
            for r in batch:
                self._execute(r)

    def _run(self) -> None:
        try:
            while True:
                req = self._next()
                if req is None:
                    return
                if req.kind == "lookup" and self._coalesce:
                    batch = [req]
                    tail = None
                    stop_after = False
                    while True:
                        try:
                            nxt = self._q.get_nowait()
                        except queue.Empty:
                            break
                        if nxt is _POISON:
                            stop_after = True
                            break
                        if nxt.kind == "lookup":
                            batch.append(nxt)
                            continue
                        tail = nxt
                        break
                    self._lookup_batch(batch)
                    if tail is not None:
                        self._execute(tail)
                    if stop_after:
                        return
                else:
                    self._execute(req)
        except BaseException as e:  # infrastructure failure: fail everything
            self._error = e
            self._fail_pending(RuntimeError("ServeSession worker died"), e)

    def _fail_pending(self, err: Exception, cause: "BaseException | None" = None) -> None:
        if cause is not None:
            err.__cause__ = cause
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                return
            if req is _POISON:
                continue
            req.future.set_exception(err)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Stop accepting requests, drain-stop the worker, join with a
        timeout, and surface the worker's root cause if it died.
        Idempotent."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        try:
            self._q.put(_POISON, timeout=_JOIN_TIMEOUT_S)
        except queue.Full:
            pass  # worker (if alive) still sees the stop event on next poll
        self._thread.join(_JOIN_TIMEOUT_S)
        if self._thread.is_alive():
            raise RuntimeError(
                f"ServeSession worker failed to stop within {_JOIN_TIMEOUT_S:.0f}s"
            )
        self._fail_pending(RuntimeError("ServeSession closed"))
        if self._error is not None:
            raise RuntimeError(
                "ServeSession worker died during serving"
            ) from self._error

    def __enter__(self) -> "ServeSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
