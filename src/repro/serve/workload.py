"""Scripted serving workloads: delta files, churn generators, timed replay.

A workload is a list of ``(verb, payload)`` ops — exactly the three service
verbs — produced either by parsing a *delta file* or by the seeded *churn
generator*, and executed by `run_workload` with per-verb latency capture.
Verification (the lookup checksum) happens strictly *outside* the timed
region, so reported latencies measure the serving path, not the check.

Delta file format (one op per line, ``#`` comments and blanks skipped)::

    add u v [w]      # insert edge (alias: + u v [w]); w defaults to 1
    del u v          # delete edge (alias: - u v)
    node [w]         # add one node (weight defaults to 1)
    lookup u1 u2 ... # gather labels (alias: ? u1 u2 ...)
    refine [budget]  # drain the priority buffer (alias: ! [budget])

Consecutive mutation lines (add/del/node) are grouped into one ``update``
request — the file's batching is explicit in its lookup/refine line
placement.  Parse errors are loud and carry the 1-based line number.

The churn generator (`ChurnSpec` / `churn_ops`) fabricates a mixed
insert/delete/node-add stream against a *mirror* of the current edge set,
so every generated delete targets an existing edge and every insert a
fresh pair — deterministic under its seed, replayable, and safe to apply
twice (the service-determinism test does exactly that).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.graphs.csr import CSRGraph

_MUTATION_OPS = {"add", "+", "del", "-", "node"}


@dataclasses.dataclass
class ChurnSpec:
    """Parameters of the generated churn workload.

    Spec strings look like ``churn:updates=64,ops=16,frac_del=0.25,seed=0``
    (any field below; unknown fields are loud errors).
    """

    updates: int = 64          # number of update requests
    ops: int = 16              # edge ops per update request
    frac_del: float = 0.25     # probability an op is a deletion
    node_adds: int = 0         # total new nodes, one per update from the start
    lookup_every: int = 4      # a lookup after every Nth update (0 = never)
    lookup_size: int = 256     # nodes per lookup request
    refine_every: int = 8      # a refine after every Nth update (0 = never)
    refine_budget: "int | None" = None  # None = drain the whole buffer
    seed: int = 0

    def __post_init__(self) -> None:
        if self.updates < 0 or self.ops < 1:
            raise ValueError(
                f"churn needs updates >= 0 and ops >= 1, got "
                f"updates={self.updates} ops={self.ops}")
        if not 0.0 <= self.frac_del <= 1.0:
            raise ValueError(f"frac_del must be in [0, 1], got {self.frac_del}")

    @classmethod
    def parse(cls, spec: str) -> "ChurnSpec":
        body = spec
        for prefix in ("gen:", "churn:"):
            if body.startswith(prefix):
                body = body[len(prefix):]
        kwargs: dict = {}
        if body:
            fields = {f.name: f for f in dataclasses.fields(cls)}
            for item in body.split(","):
                if not item:
                    continue
                if "=" not in item:
                    raise ValueError(
                        f"bad churn spec item {item!r} in {spec!r}: expected "
                        "key=value")
                key, val = item.split("=", 1)
                key = key.strip()
                if key not in fields:
                    raise ValueError(
                        f"unknown churn spec field {key!r} in {spec!r}: "
                        f"known fields are {sorted(fields)}")
                if key == "refine_budget" and val.strip().lower() == "none":
                    kwargs[key] = None
                elif key == "frac_del":
                    kwargs[key] = float(val)
                else:
                    kwargs[key] = int(val)
        return cls(**kwargs)


def churn_ops(g: CSRGraph, spec: ChurnSpec) -> list:
    """Generate the scripted op list for `spec` against graph `g`'s
    current edge set.  Deterministic under ``spec.seed``."""
    rng = np.random.default_rng(spec.seed)
    mirror = [(int(u), int(v)) for u, v in g.to_edge_list()]
    eset = set(mirror)
    n_live = g.n
    nodes_left = spec.node_adds
    ops: list = []
    for bi in range(spec.updates):
        inserts: list = []
        deletes: list = []
        batch_deleted: set = set()
        add_nodes = 0
        if nodes_left > 0:
            add_nodes = 1
            nodes_left -= 1
            new_id = n_live
            n_live += 1
            # attach the new node immediately so node adds exercise more
            # than the empty-adjacency Fennel placement
            v = int(rng.integers(n_live - 1))
            inserts.append((new_id, v, 1.0))
            eset.add((v, new_id))
            mirror.append((v, new_id))
        for _ in range(spec.ops):
            do_del = bool(rng.random() < spec.frac_del) and bool(mirror)
            if not do_del:
                e = None
                for _try in range(64):
                    u = int(rng.integers(n_live))
                    v = int(rng.integers(n_live))
                    if u == v:
                        continue
                    cand = (min(u, v), max(u, v))
                    # re-inserting an edge deleted earlier in this batch
                    # would be un-deleted by the service's insert-before-
                    # delete batch order — skip those pairs
                    if cand in eset or cand in batch_deleted:
                        continue
                    e = cand
                    break
                if e is None:
                    do_del = bool(mirror)
                    if not do_del:
                        continue
                else:
                    inserts.append((e[0], e[1], 1.0))
                    eset.add(e)
                    mirror.append(e)
            if do_del:
                j = int(rng.integers(len(mirror)))
                e = mirror[j]
                mirror[j] = mirror[-1]
                mirror.pop()
                eset.discard(e)
                batch_deleted.add(e)
                deletes.append(e)
        ops.append(("update", {
            "add_nodes": add_nodes if add_nodes else None,
            "insert_edges": inserts if inserts else None,
            "delete_edges": deletes if deletes else None,
        }))
        if spec.lookup_every and (bi + 1) % spec.lookup_every == 0:
            ops.append(("lookup",
                        rng.integers(n_live, size=spec.lookup_size)
                        .astype(np.int64)))
        if spec.refine_every and (bi + 1) % spec.refine_every == 0:
            ops.append(("refine", spec.refine_budget))
    if spec.refine_every:
        ops.append(("refine", spec.refine_budget))
    return ops


def _parse_error(path: str, lineno: int, line: str, why: str) -> ValueError:
    return ValueError(f"{path}:{lineno}: bad delta line {line!r}: {why}")


def load_delta_file(path: str) -> list:
    """Parse a delta file (module docstring has the grammar) into the
    ``(verb, payload)`` op list `run_workload` consumes."""
    ops: list = []
    pending: "dict | None" = None

    def flush() -> None:
        nonlocal pending
        if pending is not None:
            ops.append(("update", pending))
            pending = None

    def mutation() -> dict:
        nonlocal pending
        if pending is None:
            pending = {"add_nodes": None, "insert_edges": None,
                       "delete_edges": None}
        return pending

    with open(path, "r", encoding="utf-8") as fh:
        for lineno, raw in enumerate(fh, start=1):
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            op, args = parts[0].lower(), parts[1:]
            try:
                if op in ("add", "+"):
                    if len(args) not in (2, 3):
                        raise ValueError("expected: add u v [w]")
                    u, v = int(args[0]), int(args[1])
                    w = float(args[2]) if len(args) == 3 else 1.0
                    p = mutation()
                    p["insert_edges"] = (p["insert_edges"] or [])
                    p["insert_edges"].append((u, v, w))
                elif op in ("del", "-"):
                    if len(args) != 2:
                        raise ValueError("expected: del u v")
                    p = mutation()
                    p["delete_edges"] = (p["delete_edges"] or [])
                    p["delete_edges"].append((int(args[0]), int(args[1])))
                elif op == "node":
                    if len(args) > 1:
                        raise ValueError("expected: node [w]")
                    w = float(args[0]) if args else 1.0
                    p = mutation()
                    p["add_nodes"] = (p["add_nodes"] or [])
                    p["add_nodes"].append(w)
                elif op in ("lookup", "?"):
                    if not args:
                        raise ValueError("expected: lookup u1 [u2 ...]")
                    flush()
                    ops.append(("lookup",
                                np.asarray([int(a) for a in args],
                                           dtype=np.int64)))
                elif op in ("refine", "!"):
                    if len(args) > 1:
                        raise ValueError("expected: refine [budget]")
                    flush()
                    ops.append(("refine", int(args[0]) if args else None))
                else:
                    raise ValueError(
                        f"unknown op {op!r} (know: add/+ del/- node lookup/? "
                        "refine/!)")
            except ValueError as e:
                raise _parse_error(path, lineno, line, str(e)) from None
    flush()
    return ops


def _lat_summary(samples: "list[float]") -> dict:
    if not samples:
        return {"count": 0, "total_s": 0.0, "mean_ms": 0.0,
                "p50_ms": 0.0, "p99_ms": 0.0}
    arr = np.asarray(samples, dtype=np.float64)
    return {
        "count": int(arr.size),
        "total_s": float(arr.sum()),
        "mean_ms": float(arr.mean() * 1e3),
        "p50_ms": float(np.percentile(arr, 50) * 1e3),
        "p99_ms": float(np.percentile(arr, 99) * 1e3),
    }


def run_workload(target, ops) -> dict:
    """Replay `ops` against `target` (a `PartitionService` or a
    `ServeSession`) and return per-verb latency summaries plus sustained
    rates.  Only the verb call is timed; checksum verification and
    bookkeeping happen between timed regions (the satellite fix for the
    old `serve_partition` loop, which timed its own checksum)."""
    lat: dict = {"lookup": [], "update": [], "refine": []}
    edge_ops = 0
    lookup_nodes = 0
    checksum = 0
    for kind, payload in ops:
        if kind == "lookup":
            t0 = time.perf_counter()
            out = target.lookup(payload)
            lat["lookup"].append(time.perf_counter() - t0)
            lookup_nodes += int(np.asarray(payload).size)
            checksum += int(np.asarray(out, dtype=np.int64).sum())
        elif kind == "update":
            t0 = time.perf_counter()
            out = target.update(**payload)
            lat["update"].append(time.perf_counter() - t0)
            edge_ops += (out["edge_inserts"] + out["edge_deletes"]
                         + len(out["nodes_added"]))
        elif kind == "refine":
            t0 = time.perf_counter()
            target.refine(payload)
            lat["refine"].append(time.perf_counter() - t0)
        else:
            raise ValueError(
                f"unknown workload verb {kind!r} (know: lookup/update/refine)")
    out = {verb: _lat_summary(ts) for verb, ts in lat.items()}
    upd_s = out["update"]["total_s"]
    lkp_s = out["lookup"]["total_s"]
    out["update"]["edge_ops"] = edge_ops
    out["update"]["updates_per_s"] = (edge_ops / upd_s) if upd_s > 0 else 0.0
    out["lookup"]["nodes"] = lookup_nodes
    out["lookup"]["lookups_per_s"] = (lookup_nodes / lkp_s) if lkp_s > 0 else 0.0
    out["lookup_checksum"] = checksum
    return out
