"""Per-(arch x shape) step construction for the dry-run and the trainers.

build_cell() returns everything needed to lower one cell on one mesh:
  step_fn        the jittable function (train / prefill / decode / serve)
  arg_structs    ShapeDtypeStructs for every argument (params included —
                 nothing is ever allocated)
  in_shardings / out_shardings / donate
  model_flops    6*N*D (dense) or 6*N_active*D (MoE) for §Roofline

Leading batch/node/edge dims that are not divisible by the DP degree are
padded up (masked padding rows — standard practice; noted per cell).
"""
from __future__ import annotations

import dataclasses
import math

import jax  # repro: noqa RPR001 -- train-step module; only reached from train-arch entry points
import jax.numpy as jnp  # repro: noqa RPR001 -- train-step module
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # repro: noqa RPR001 -- train-step module

from repro.configs import get_arch
from repro.configs.base import ShapeDef
from repro.distributed.sharding import (
    ShardingRules, lm_sharding_rules, lm_decode_sharding_rules,
    gnn_sharding_rules, dlrm_sharding_rules, param_shardings, batch_shardings,
)
from repro.launch.mesh import dp_size
from repro.models import transformer as tfm
from repro.models import gnn as gnn_mod
from repro.models import dlrm as dlrm_mod
from repro.train.adamw import AdamW


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step_fn: object
    arg_structs: tuple
    in_shardings: tuple
    out_shardings: object
    donate: tuple
    model_flops: float
    notes: str = ""
    skip: str | None = None


def _eval_shape(fn, *args):
    return jax.eval_shape(fn, *args)


def _pad_dim0(struct: jax.ShapeDtypeStruct, mult: int) -> jax.ShapeDtypeStruct:
    if not struct.shape:
        return struct
    d0 = struct.shape[0]
    target = math.ceil(d0 / mult) * mult
    if target == d0:
        return struct
    return jax.ShapeDtypeStruct((target,) + struct.shape[1:], struct.dtype)


def _pad_tree_dim0(tree, mult: int):
    return jax.tree.map(lambda s: _pad_dim0(s, mult), tree)


def _shardings_with_fallback(rules: ShardingRules, mesh: Mesh, tree):
    """batch shardings, replicating any leaf whose dim0 doesn't divide."""
    base = batch_shardings(rules, mesh, tree)

    def fix(struct, sh):
        spec = list(sh.spec) + [None] * (len(struct.shape) - len(sh.spec))
        for i, (dim, ax) in enumerate(zip(struct.shape, spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if dim % n != 0:
                spec[i] = None  # fallback: replicate this dim
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(fix, tree, base)


# =====================================================================
# LM cells
# =====================================================================

def _lm_model_flops(cfg, tokens: int, kind: str) -> float:
    n_active = cfg.active_param_count()
    per_tok = 6.0 * n_active if kind == "train" else 2.0 * n_active
    return per_tok * tokens


def _build_lm_cell(spec, cfg, shape: ShapeDef, mesh: Mesh,
                   attn_mode: str = "seq") -> Cell:
    """attn_mode: 'seq' (baseline — sequence-parallel attention, valid for
    any head count) or 'head_tp' (§Perf H1 — Megatron head-parallel QKVO;
    requires n_heads % tp == 0; kv heads shard only when they divide)."""
    tp = mesh.shape["model"]
    head_tp = attn_mode == "head_tp" and cfg.n_heads % tp == 0
    kv_tp = head_tp and cfg.n_kv_heads % tp == 0
    rules = lm_sharding_rules(moe=cfg.n_experts > 0, head_tp=head_tp, kv_tp=kv_tp)
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    # sequence parallelism: batch over dp, sequence over the TP axis
    seq_spec = P(dp_axes, "model", None)
    if head_tp:
        # (B, S, H, hd): heads over the TP axis; kv heads likewise if they
        # divide, else replicated (GQA-native flash handles both)
        q_spec = P(dp_axes, None, "model", None)
        kv_spec = P(dp_axes, None, "model" if kv_tp else None, None)
    else:
        # q sequence-sharded over 'model' (each device owns a q block vs
        # replicated-on-model KV) — valid for every head count
        q_spec = P(dp_axes, "model", None, None)
        kv_spec = P(dp_axes, None, None, None)

    def attn_shard(x, role):
        spec_ = q_spec if role == "q" else kv_spec
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_))

    def act_shard(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, seq_spec))
        return x

    params_struct = _eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = param_shardings(rules, mesh, params_struct)
    batch_struct = spec.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = AdamW()
        opt_struct = _eval_shape(opt.init, params_struct)
        o_shard = param_shardings(rules, mesh, opt_struct._asdict())
        o_shard = type(opt_struct)(**o_shard)
        b_shard = _shardings_with_fallback(rules, mesh, batch_struct)
        # gradient-accumulation microbatches: activation memory scales 1/m;
        # the per-microbatch reduce-scatter also overlaps with the next
        # microbatch's backward under XLA's latency-hiding scheduler.
        micro = 2 if cfg.d_model < 8192 else 8

        def shard_like_params(tree):
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, p_shard
            )

        def train_step(params, opt_state, batch):
            tfm.set_activation_sharding(act_shard)
            tfm.set_attn_sharding(attn_shard)
            if cfg.n_experts:
                tfm.set_moe_spmd(mesh, x_spec=seq_spec)

            def loss_of(p, b):
                return tfm.loss_fn(p, b, cfg)

            if micro == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
            else:
                def mb(i):
                    return jax.tree.map(
                        lambda x: jax.lax.dynamic_slice_in_dim(
                            x, i * (x.shape[0] // micro), x.shape[0] // micro, 0
                        ),
                        batch,
                    )

                def body(carry, i):
                    acc_l, acc_g = carry
                    l_i, g_i = jax.value_and_grad(loss_of)(params, mb(i))
                    g_i = jax.tree.map(lambda x: x.astype(jnp.float32), g_i)
                    acc_g = shard_like_params(
                        jax.tree.map(jnp.add, acc_g, g_i)
                    )
                    return (acc_l + l_i, acc_g), None

                zero_g = shard_like_params(
                    jax.tree.map(
                        lambda s: jnp.zeros(s.shape, jnp.float32), params
                    )
                )
                # unroll in analysis mode (scan_unroll>1) so cost_analysis
                # sees every microbatch, not just one while-loop body
                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros(()), zero_g), jnp.arange(micro),
                    unroll=micro if cfg.scan_unroll > 1 else 1,
                )
                loss = loss / micro
                grads = jax.tree.map(lambda g: g / micro, grads)
            new_p, new_o, gnorm = opt.update(grads, opt_state, params)
            tfm.set_activation_sharding(None)
            tfm.set_attn_sharding(None)
            tfm.set_moe_spmd(None)
            return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

        return Cell(
            arch_id=spec.arch_id, shape_name=shape.name, kind="train",
            step_fn=train_step,
            arg_structs=(params_struct, opt_struct, batch_struct),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate=(0, 1),
            model_flops=_lm_model_flops(
                cfg, shape.dims["batch"] * shape.dims["seq"], "train"
            ),
        )

    if shape.kind == "prefill":
        # prefill is compute-shaped like training: FSDP weights +
        # sequence-parallel attention (decode rules would psum huge
        # (B, 32k, d) activations per projection)
        rules_d = rules
        p_shard_d = param_shardings(rules_d, mesh, params_struct)
        b_shard = _shardings_with_fallback(rules_d, mesh, batch_struct)
        max_len = shape.dims["seq"]

        def prefill_step(params, batch):
            tfm.set_activation_sharding(act_shard)
            tfm.set_attn_sharding(attn_shard)
            if cfg.n_experts:
                tfm.set_moe_spmd(mesh, x_spec=seq_spec)
            out = tfm.forward_prefill(params, batch["tokens"], cfg, max_len)
            tfm.set_activation_sharding(None)
            tfm.set_attn_sharding(None)
            tfm.set_moe_spmd(None)
            return out

        # output: (logits, cache) — pin the cache to the decode layout so
        # XLA does not materialize it replicated (412 GB at moonshot 32k)
        cache_struct = {
            "k": jax.ShapeDtypeStruct(
                (cfg.n_layers, shape.dims["batch"], max_len, cfg.n_kv_heads, cfg.d_head),
                cfg.jdtype),
            "v": jax.ShapeDtypeStruct(
                (cfg.n_layers, shape.dims["batch"], max_len, cfg.n_kv_heads, cfg.d_head),
                cfg.jdtype),
            "pos": jax.ShapeDtypeStruct((shape.dims["batch"],), jnp.int32),
        }
        cache_shard = _shardings_with_fallback(rules_d, mesh, {"cache": cache_struct})["cache"]
        out_sh = (None, cache_shard)
        return Cell(
            arch_id=spec.arch_id, shape_name=shape.name, kind="prefill",
            step_fn=prefill_step,
            arg_structs=(params_struct, batch_struct),
            in_shardings=(p_shard_d, b_shard),
            out_shardings=out_sh,
            donate=(),
            model_flops=_lm_model_flops(
                cfg, shape.dims["batch"] * shape.dims["seq"], "prefill"
            ),
        )

    # decode (incl. long_500k)
    rules_d = lm_decode_sharding_rules()
    p_shard_d = param_shardings(rules_d, mesh, params_struct)
    b_shard = _shardings_with_fallback(rules_d, mesh, batch_struct)

    def decode_step(params, batch):
        if cfg.n_experts:
            tfm.set_moe_spmd(mesh, x_spec=P(dp_axes, None, None))  # decode: (B,1,d)
        logits, cache = tfm.forward_decode(params, batch["tokens"], batch["cache"], cfg)
        tfm.set_moe_spmd(None)
        return logits, cache

    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind="decode",
        step_fn=decode_step,
        arg_structs=(params_struct, batch_struct),
        in_shardings=(p_shard_d, b_shard),
        out_shardings=(None, b_shard["cache"]),  # new cache keeps its layout
        donate=(1,),  # donate the cache
        model_flops=_lm_model_flops(cfg, shape.dims["batch"], "decode"),
    )


# =====================================================================
# GNN cells
# =====================================================================

_GNN_LOSS = {
    "egnn": (gnn_mod.egnn_loss, "d_in"),
    "meshgraphnet": (gnn_mod.mgn_loss, "d_node_in"),
    "schnet": (gnn_mod.schnet_loss, None),
    "graphsage-reddit": (gnn_mod.sage_loss, "d_in"),
}

_GNN_INIT = {
    "egnn": gnn_mod.egnn_init,
    "meshgraphnet": gnn_mod.mgn_init,
    "schnet": gnn_mod.schnet_init,
    "graphsage-reddit": gnn_mod.sage_init,
}

_GNN_FLOP_FACTOR = {  # ~flops per (edge + node) unit per layer: 2*d^2-ish
    "egnn": 6, "meshgraphnet": 10, "schnet": 6, "graphsage-reddit": 4,
}


def _gnn_model_flops(arch_id: str, cfg, shape: ShapeDef) -> float:
    n, e = shape.dims["n"], shape.dims["e_dir"]
    d = getattr(cfg, "d_hidden", 64)
    layers = getattr(cfg, "n_layers", getattr(cfg, "n_interactions", 3))
    # message MLP ~ 2*d^2 per edge, node MLP ~ 2*d^2 per node, x3 for bwd
    return 3.0 * layers * (e + n) * 2.0 * d * d * _GNN_FLOP_FACTOR[arch_id] / 4.0


def _build_gnn_cell(spec, cfg, shape: ShapeDef, mesh: Mesh) -> Cell:
    rules = gnn_sharding_rules()
    f = shape.dims["f"]
    loss_fn_base, din_field = _GNN_LOSS[spec.arch_id]
    if din_field is not None:
        cfg = dataclasses.replace(cfg, **{din_field: f})
    if spec.arch_id == "graphsage-reddit":
        n_cls = 41 if shape.name == "minibatch_lg" else 47
        cfg = dataclasses.replace(cfg, n_classes=n_cls)

    params_struct = _eval_shape(
        lambda: _GNN_INIT[spec.arch_id](jax.random.PRNGKey(0), cfg)
    )
    p_shard = param_shardings(rules, mesh, params_struct)
    dp = dp_size(mesh)
    batch_struct = _pad_tree_dim0(spec.input_specs(cfg, shape), dp)
    b_shard = _shardings_with_fallback(rules, mesh, batch_struct)

    n_graphs = shape.dims.get("graphs", 1)

    def loss_fn(p, b):
        if spec.arch_id == "schnet":
            b = dict(b)
            b["n_graphs"] = max(
                math.ceil(n_graphs / dp) * dp, dp
            ) if n_graphs > 1 else 1
        return loss_fn_base(p, b, cfg)

    opt = AdamW()
    opt_struct = _eval_shape(opt.init, params_struct)
    o_shard = param_shardings(rules, mesh, opt_struct._asdict())
    o_shard = type(opt_struct)(**o_shard)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_o, gnorm = opt.update(grads, opt_state, params)
        return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind="train",
        step_fn=train_step,
        arg_structs=(params_struct, opt_struct, batch_struct),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, None),
        donate=(0, 1),
        model_flops=_gnn_model_flops(spec.arch_id, cfg, shape),
        notes=f"leading dims padded to multiples of dp={dp}",
    )


# =====================================================================
# DLRM cells
# =====================================================================

def _dlrm_model_flops(cfg, shape: ShapeDef) -> float:
    b = shape.dims.get("batch", 1)
    mlp = 0
    sizes = (cfg.n_dense,) + cfg.bot_mlp
    mlp += sum(2 * a * o for a, o in zip(sizes, sizes[1:]))
    d_top = cfg.n_interact + cfg.embed_dim
    sizes = (d_top,) + cfg.top_mlp
    mlp += sum(2 * a * o for a, o in zip(sizes, sizes[1:]))
    interact = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    factor = 3.0 if shape.kind == "train" else 1.0
    flops = factor * b * (mlp + interact)
    if shape.kind == "retrieval":
        flops += 2.0 * shape.dims["candidates"] * cfg.embed_dim
    return flops


def _build_dlrm_cell(spec, cfg, shape: ShapeDef, mesh: Mesh) -> Cell:
    rules = dlrm_sharding_rules()
    params_struct = _eval_shape(lambda: dlrm_mod.dlrm_init(jax.random.PRNGKey(0), cfg))
    p_shard = param_shardings(rules, mesh, params_struct)
    batch_struct = spec.input_specs(cfg, shape)
    b_shard = _shardings_with_fallback(rules, mesh, batch_struct)

    if shape.kind == "train":
        opt = AdamW()
        opt_struct = _eval_shape(opt.init, params_struct)
        o_shard = param_shardings(rules, mesh, opt_struct._asdict())
        o_shard = type(opt_struct)(**o_shard)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: dlrm_mod.dlrm_loss(p, batch, cfg)
            )(params)
            new_p, new_o, gnorm = opt.update(grads, opt_state, params)
            return new_p, new_o, {"loss": loss, "grad_norm": gnorm}

        return Cell(
            arch_id=spec.arch_id, shape_name=shape.name, kind="train",
            step_fn=train_step,
            arg_structs=(params_struct, opt_struct, batch_struct),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate=(0, 1),
            model_flops=_dlrm_model_flops(cfg, shape),
        )

    if shape.kind == "retrieval":
        def retrieval_step(params, batch):
            return dlrm_mod.dlrm_retrieval(params, batch, cfg)
        fn = retrieval_step
    else:
        def serve_step(params, batch):
            return dlrm_mod.dlrm_forward(params, batch, cfg)
        fn = serve_step

    return Cell(
        arch_id=spec.arch_id, shape_name=shape.name, kind=shape.kind,
        step_fn=fn,
        arg_structs=(params_struct, batch_struct),
        in_shardings=(p_shard, b_shard),
        out_shardings=None,
        donate=(),
        model_flops=_dlrm_model_flops(cfg, shape),
    )


# =====================================================================
# dispatch
# =====================================================================

def build_cell(arch_id: str, shape_name: str, mesh: Mesh, *, unroll: bool = False,
               cfg_override=None, attn_mode: str = "seq") -> Cell:
    """unroll=True fully unrolls the LM layer scan so cost_analysis and the
    collective-bytes parse see every layer (dry-run analysis mode); the
    rolled scan remains the production/training path. cfg_override replaces
    the arch config entirely (roofline two-point fits)."""
    spec = get_arch(arch_id)
    shape = spec.shapes[shape_name]
    if shape.skip:
        return Cell(
            arch_id=arch_id, shape_name=shape_name, kind=shape.kind,
            step_fn=None, arg_structs=(), in_shardings=(), out_shardings=None,
            donate=(), model_flops=0.0, skip=shape.skip,
        )
    cfg = cfg_override if cfg_override is not None else spec.full_config()
    if spec.family == "lm":
        if unroll and cfg_override is None:
            cfg = dataclasses.replace(cfg, scan_unroll=cfg.n_layers)
        return _build_lm_cell(spec, cfg, shape, mesh, attn_mode=attn_mode)
    if spec.family == "gnn":
        return _build_gnn_cell(spec, cfg, shape, mesh)
    if spec.family == "recsys":
        return _build_dlrm_cell(spec, cfg, shape, mesh)
    raise ValueError(spec.family)


def lower_cell(cell: Cell, mesh: Mesh):
    """jit + lower (no compile). Returns the Lowered object."""
    jitted = jax.jit(
        cell.step_fn,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate,
    )
    with mesh:
        return jitted.lower(*cell.arg_structs)
