"""Batched serving driver (LM decode / DLRM scoring / graph placement).

Demonstrates the inference path end-to-end on CPU with the smoke configs:
prefill a batch of prompts, decode N tokens with the KV cache (SWA archs go
through the Pallas sliding-window kernel), report tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b --tokens 32

``--arch partition`` serves the placement workload through the resident
serving subsystem (`repro.serve`): the graph source is partitioned once
through `repro.api`, promoted into a `PartitionService`, and batched
node->block lookups are answered by a `ServeSession` — the query shape the
GNN training loop and the sharded embedding path issue.  The timed region
contains only the lookups; checksum verification runs afterwards, against
an independent gather of the result labels.

  PYTHONPATH=src python -m repro.launch.serve --arch partition \
      --graph gen:grid:side=64 --k 16 --driver buffcut

The LM / DLRM model stacks (jax, `repro.configs`, both model modules) are
imported lazily inside their serve functions, so partition mode never pays
— or requires — the accelerator stack (the same motivation as
`distributed/__init__`'s PEP 562 laziness).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve_lm(arch_id: str, batch: int, prompt_len: int, gen_tokens: int) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models import transformer as tfm

    spec = get_arch(arch_id)
    cfg = spec.smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    max_len = prompt_len + gen_tokens + 1

    prefill = jax.jit(lambda p, t: tfm.forward_prefill(p, t, cfg, max_len))
    decode = jax.jit(lambda p, t, c: tfm.forward_decode(p, t, c, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen_tokens):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    total = batch * gen_tokens
    print(
        f"arch={arch_id} batch={batch} prefill({prompt_len} tok) {t_prefill*1e3:.0f}ms, "
        f"decode {gen_tokens} tok x {batch} = {total} tok in {t_decode*1e3:.0f}ms "
        f"({total / max(t_decode, 1e-9):.0f} tok/s)"
    )


def serve_dlrm(batch: int) -> None:
    import jax

    from repro.configs import get_arch
    from repro.models import dlrm as dlrm_mod

    spec = get_arch("dlrm-mlperf")
    cfg = spec.smoke_config()
    params = dlrm_mod.dlrm_init(jax.random.PRNGKey(0), cfg)
    b = spec.smoke_batch(cfg, 0)
    fwd = jax.jit(lambda p, b: dlrm_mod.dlrm_forward(p, b, cfg))
    t0 = time.perf_counter()
    for _ in range(10):
        scores = fwd(params, b)
    jax.block_until_ready(scores)
    dt = (time.perf_counter() - t0) / 10
    print(f"dlrm serve: batch={b['dense'].shape[0]} {dt*1e6:.0f} us/batch")


def serve_partition(source: str, k: int, driver: str, batch: int, queries: int) -> None:
    """Placement-as-a-service through `repro.serve`: one
    `repro.api.partition` call builds the resident service; serving is
    batched node->block lookups via a `ServeSession`.  Only the lookups are
    timed — the checksum verification happens afterwards against an
    independent gather (the old loop timed its own verification, so the
    reported lookups/s was dominated by the per-batch ``int()`` checksum)."""
    from repro.api import partition
    from repro.serve import ServeSession

    res = partition(source, k=k, driver=driver)
    service = res.into_service()
    n = service.n
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, n, batch).astype(np.int64) for _ in range(queries)]
    with ServeSession(service) as sess:
        t0 = time.perf_counter()
        outs = [sess.lookup(q) for q in reqs]
        dt = time.perf_counter() - t0
    # verification — outside the timed region
    checksum = 0
    for q, out in zip(reqs, outs):
        expect = res.labels[q]
        if not np.array_equal(out, expect):
            raise RuntimeError(
                "served labels diverged from the partition result "
                f"(batch of {q.shape[0]} lookups)"
            )
        checksum += int(out.sum())
    total = batch * queries
    print(
        f"partition serve: driver={res.provenance['driver']} n={n} k={res.k} "
        f"cut_ratio={res.cut_ratio:.4f} balance={res.balance:.3f} | "
        f"{queries} batches x {batch} lookups in {dt*1e3:.1f}ms "
        f"({total / max(dt, 1e-9):.0f} lookups/s, checksum={checksum})"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--graph", default="gen:grid:side=64",
                    help="partition mode: graph source (path or gen: spec)")
    ap.add_argument("--k", type=int, default=16, help="partition mode: blocks")
    ap.add_argument("--driver", default="buffcut",
                    help="partition mode: registry driver name")
    ap.add_argument("--queries", type=int, default=64,
                    help="partition mode: lookup batches to serve")
    args = ap.parse_args()
    if args.arch == "partition":
        serve_partition(args.graph, args.k, args.driver, args.batch, args.queries)
    elif args.arch == "dlrm-mlperf":
        serve_dlrm(args.batch)
    else:
        serve_lm(args.arch, args.batch, args.prompt, args.tokens)


if __name__ == "__main__":
    main()
