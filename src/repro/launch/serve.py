"""Batched serving driver (LM decode / DLRM scoring / graph placement).

Demonstrates the inference path end-to-end on CPU with the smoke configs:
prefill a batch of prompts, decode N tokens with the KV cache (SWA archs go
through the Pallas sliding-window kernel), report tokens/s.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b --tokens 32

``--arch partition`` serves the placement workload instead: the graph
source is partitioned once through `repro.api` (any registered driver, any
source kind the API resolves) and the resulting placement table answers
batched node->block lookups — the query shape the GNN training loop and
the sharded embedding path issue.

  PYTHONPATH=src python -m repro.launch.serve --arch partition \
      --graph gen:grid:side=64 --k 16 --driver buffcut
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.models import dlrm as dlrm_mod


def serve_lm(arch_id: str, batch: int, prompt_len: int, gen_tokens: int) -> None:
    spec = get_arch(arch_id)
    cfg = spec.smoke_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    max_len = prompt_len + gen_tokens + 1

    prefill = jax.jit(lambda p, t: tfm.forward_prefill(p, t, cfg, max_len))
    decode = jax.jit(lambda p, t, c: tfm.forward_decode(p, t, c, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen_tokens):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    total = batch * gen_tokens
    print(
        f"arch={arch_id} batch={batch} prefill({prompt_len} tok) {t_prefill*1e3:.0f}ms, "
        f"decode {gen_tokens} tok x {batch} = {total} tok in {t_decode*1e3:.0f}ms "
        f"({total / max(t_decode, 1e-9):.0f} tok/s)"
    )


def serve_dlrm(batch: int) -> None:
    spec = get_arch("dlrm-mlperf")
    cfg = spec.smoke_config()
    params = dlrm_mod.dlrm_init(jax.random.PRNGKey(0), cfg)
    b = spec.smoke_batch(cfg, 0)
    fwd = jax.jit(lambda p, b: dlrm_mod.dlrm_forward(p, b, cfg))
    t0 = time.perf_counter()
    for _ in range(10):
        scores = fwd(params, b)
    jax.block_until_ready(scores)
    dt = (time.perf_counter() - t0) / 10
    print(f"dlrm serve: batch={b['dense'].shape[0]} {dt*1e6:.0f} us/batch")


def serve_partition(source: str, k: int, driver: str, batch: int, queries: int) -> None:
    """Placement-as-a-service: one `repro.api.partition` call builds the
    placement table; serving is batched node->block lookups against it."""
    from repro.api import partition

    res = partition(source, k=k, driver=driver)
    n = res.labels.shape[0]
    rng = np.random.default_rng(0)
    reqs = [rng.integers(0, n, batch) for _ in range(queries)]
    t0 = time.perf_counter()
    checksum = 0
    for q in reqs:
        checksum += int(res.labels[q].sum())
    dt = time.perf_counter() - t0
    total = batch * queries
    print(
        f"partition serve: driver={res.provenance['driver']} n={n} k={res.k} "
        f"cut_ratio={res.cut_ratio:.4f} balance={res.balance:.3f} | "
        f"{queries} batches x {batch} lookups in {dt*1e3:.1f}ms "
        f"({total / max(dt, 1e-9):.0f} lookups/s, checksum={checksum})"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--graph", default="gen:grid:side=64",
                    help="partition mode: graph source (path or gen: spec)")
    ap.add_argument("--k", type=int, default=16, help="partition mode: blocks")
    ap.add_argument("--driver", default="buffcut",
                    help="partition mode: registry driver name")
    ap.add_argument("--queries", type=int, default=64,
                    help="partition mode: lookup batches to serve")
    args = ap.parse_args()
    if args.arch == "partition":
        serve_partition(args.graph, args.k, args.driver, args.batch, args.queries)
    elif args.arch == "dlrm-mlperf":
        serve_dlrm(args.batch)
    else:
        serve_lm(args.arch, args.batch, args.prompt, args.tokens)


if __name__ == "__main__":
    main()
