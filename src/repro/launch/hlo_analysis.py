"""Compiled-HLO analysis: collective bytes + roofline terms.

cost_analysis() gives FLOPs and HBM bytes but not collective traffic; we
parse the post-SPMD HLO text and sum the *output* shape bytes of every
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute). Hardware constants are TPU v5e per the assignment:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
# matches: "%name = TYPE[dims]{layout} all-gather(...)" and tuple forms
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9\[\],{}:#\s\.]*?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind. '-done' ops are skipped (the
    '-start' op already carries the shape) to avoid double counting."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        if f"{m.group(2)}-done(" in stripped:
            continue
        shape_part = m.group(1)
        kind = m.group(2)
        out[kind] += _shape_bytes(shape_part)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def remat_duplication(hlo_text: str) -> float:
    """Rough remat waste signal: ratio of fusion ops inside while-loop bodies
    vs total (higher after remat)."""
    n_fusion = hlo_text.count(" fusion(")
    n_all = max(hlo_text.count(" = "), 1)
    return n_fusion / n_all


@dataclasses.dataclass
class RooflineTerms:
    """All byte/flop counts are PER-DEVICE: under SPMD the compiled module
    (and its cost_analysis) is the per-device program, so the assignment's
    `HLO_FLOPs / (chips x peak)` equals `flops_per_device / peak`."""

    flops: float              # HLO FLOPs per device
    hbm_bytes: float          # HLO bytes accessed per device
    coll_bytes: float         # collective output bytes per device
    n_devices: int
    model_flops: float = 0.0  # 6*N*D useful flops for the WHOLE step

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
        }


def analyze_compiled(compiled, n_devices: int, model_flops: float = 0.0) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    return RooflineTerms(
        flops=flops, hbm_bytes=byts, coll_bytes=float(coll["total"]),
        n_devices=n_devices, model_flops=model_flops,
    )
