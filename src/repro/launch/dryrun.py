import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective analyses.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.
(`from __future__` is therefore deliberately absent here.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --json out.json
"""
import argparse
import json
import sys
import time
import traceback

import jax  # noqa: F401 # repro: noqa RPR001 -- dry-run lowering needs the device runtime up front

from repro.configs import ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell
from repro.launch.hlo_analysis import analyze_compiled, collective_bytes


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             unroll: bool = False) -> dict:
    # unroll=False: the PRODUCTION (rolled-scan) artifact is what must
    # compile and fit; loop-corrected cost extraction lives in
    # benchmarks.roofline (two-point unrolled fit).
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    cell = build_cell(arch_id, shape_name, mesh, unroll=unroll)
    if cell.skip:
        return {
            "arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skip", "reason": cell.skip,
        }
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    terms = analyze_compiled(compiled, n_dev, cell.model_flops)
    coll = collective_bytes(compiled.as_text())
    out = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "kind": cell.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": {
            "args": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
            # donated args alias their outputs: count aliased bytes once
            "peak": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "collectives": {k: int(v) for k, v in coll.items()},
        "roofline": terms.as_dict(),
        "notes": cell.notes,
    }
    if verbose:
        gb = out["bytes_per_device"]
        print(
            f"[{out['mesh']}] {arch_id} x {shape_name} ({cell.kind}): "
            f"compile {t_compile:.0f}s  peak/dev "
            f"{gb['peak'] / 1e9:.2f} GB  "
            f"coll {coll['total'] / 1e6:.1f} MB  "
            f"bottleneck={terms.bottleneck}",
            flush=True,
        )
        print(f"  memory_analysis: {mem}", flush=True)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        keep = {k: v for k, v in ca.items() if k in ("flops", "bytes accessed")}
        print(f"  cost_analysis: {keep}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None, help="append results to this JSON-lines file")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for aid, spec in ARCHS.items():
            for sname in spec.shapes:
                cells.append((aid, sname))
    else:
        assert args.arch, "--arch required unless --all"
        spec = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results, failures = [], 0
    for arch_id, shape_name in cells:
        for multi in meshes:
            try:
                res = run_cell(arch_id, shape_name, multi)
            except Exception as e:  # a failure here is a bug in our sharding
                failures += 1
                res = {
                    "arch": arch_id, "shape": shape_name,
                    "mesh": "2x16x16" if multi else "16x16",
                    "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                }
                print(f"FAIL {arch_id} x {shape_name}: {e}", flush=True)
                traceback.print_exc()
            results.append(res)
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(res) + "\n")
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    print(f"\ndry-run summary: {ok} ok, {skip} skip, {failures} FAIL", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
