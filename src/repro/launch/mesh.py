"""Production mesh factories.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512 chips).

    Axes: 'pod' (DCI data parallel), 'data' (ICI data/FSDP), 'model' (ICI
    tensor/expert parallel).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def dp_size(mesh) -> int:
    s = 1
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            s *= mesh.shape[name]
    return s
