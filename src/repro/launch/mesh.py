"""Production mesh factories.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax  # repro: noqa RPR001 -- launch-time mesh module; only reached from train-arch entry points

try:  # jax >= 0.5 (explicit-sharding API); older jax has no AxisType
    from jax.sharding import AxisType  # repro: noqa RPR001 -- launch-time mesh module
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _axis_kwargs(n_axes: int) -> dict:
    return {} if AxisType is None else {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512 chips).

    Axes: 'pod' (DCI data parallel), 'data' (ICI data/FSDP), 'model' (ICI
    tensor/expert parallel).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kwargs(2))


def dp_size(mesh) -> int:
    s = 1
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            s *= mesh.shape[name]
    return s
