"""End-to-end training driver.

Runs any `--arch` at its smoke (CPU) or full (pod) scale with the real
substrate: sharded params, AdamW, fault-tolerant loop, checkpoints, data
pipeline. On this container use --preset smoke (reduced config, 1 device);
on a pod the same code path takes --preset full --mesh single|multi.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --preset smoke --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax  # repro: noqa RPR001 -- train entry point; jax is its purpose
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tfm
from repro.models import dlrm as dlrm_mod
from repro.train.adamw import AdamW
from repro.train.loop import make_train_step, TrainLoop, LoopConfig
from repro.train.checkpoint import CheckpointManager
from repro.train.data import token_batches, gnn_batches, dlrm_batches


def build_training(arch_id: str, preset: str, batch: int, seq: int):
    spec = get_arch(arch_id)
    cfg = spec.smoke_config() if preset == "smoke" else spec.full_config()
    rng = jax.random.PRNGKey(0)
    if spec.family == "lm":
        params = tfm.init_params(rng, cfg)
        loss = lambda p, b: tfm.loss_fn(p, b, cfg)
        data = token_batches(cfg.vocab, batch, seq)
    elif spec.family == "gnn":
        from repro.launch.steps import _GNN_INIT, _GNN_LOSS  # noqa: PLC0415
        init_fn = _GNN_INIT[arch_id]
        loss_base, _ = _GNN_LOSS[arch_id]
        params = init_fn(rng, cfg)
        loss = lambda p, b: loss_base(p, b, cfg)
        data = gnn_batches(lambda s: spec.smoke_batch(cfg, s))
    else:
        params = dlrm_mod.dlrm_init(rng, cfg)
        loss = lambda p, b: dlrm_mod.dlrm_loss(p, b, cfg)
        data = dlrm_batches(cfg, batch)
    return cfg, params, loss, data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg, params, loss, data = build_training(args.arch, args.preset, args.batch, args.seq)
    opt = AdamW(lr=args.lr, warmup_steps=min(args.steps // 10 + 1, 100))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(loss, opt))
    ckpt = CheckpointManager(args.ckpt_dir)
    start = 0
    if args.resume:
        restored = ckpt.restore_latest(template=(params, opt_state))
        if restored:
            (params, opt_state), start = restored["state"], restored["step"]
            print(f"resumed from step {start}")
    loop = TrainLoop(
        step_fn, ckpt,
        LoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every),
    )
    t0 = time.time()
    (params, opt_state), history = loop.run(params, opt_state, data, start_step=start)
    dt = time.time() - t0
    n = max(len(history), 1)
    print(
        f"arch={args.arch} steps={len(history)} "
        f"loss {history[0]:.4f} -> {history[-1]:.4f} "
        f"({dt:.1f}s, {dt / n * 1e3:.0f} ms/step, "
        f"stragglers={len(loop.stragglers)}, retries={loop.retries})"
    )
    assert not np.isnan(history[-1]), "training diverged"


if __name__ == "__main__":
    main()
