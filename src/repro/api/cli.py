"""``python -m repro`` — the command-line twin of `repro.api`.

    python -m repro partition graph.bcsr -k 16 --driver pipelined \
        --engine jax --ordering bfs --json out.json
    python -m repro gen grid -o mesh.bcsr --param side=64
    python -m repro list -v

`partition` resolves any source the API accepts (METIS text, packed binary,
``gen:`` spec), runs the chosen registry driver, prints a one-line quality
summary and optionally writes the full `PartitionResult` JSON.  `gen`
synthesizes an instance family to disk (packed by default, METIS text with
``--format metis``).  `list` prints the registry.
"""
from __future__ import annotations

import argparse
import sys


def _add_partition_parser(sub: "argparse._SubParsersAction") -> None:
    p = sub.add_parser(
        "partition",
        help="partition a graph source through the unified API",
        description="Partition SOURCE (CSR file path or gen:<family>:... spec) "
                    "with any registered driver.",
    )
    p.add_argument("source", nargs="?", default=None,
                   help="METIS text / packed binary path, or gen:<family>:k=v,... spec "
                        "(optional with --resume when the checkpoint recorded it)")
    p.add_argument("-k", type=int, default=None, help="number of blocks")
    p.add_argument("--driver", default="buffcut",
                   help="registry name or alias (see `python -m repro list`)")
    p.add_argument("--engine", default="auto",
                   choices=["auto", "sparse", "ell", "jax"],
                   help="multilevel engine")
    p.add_argument("--ordering", default="natural",
                   choices=["natural", "random", "bfs", "konect"],
                   help="stream ordering (realized on disk for disk sources)")
    p.add_argument("--order-seed", type=int, default=0)
    p.add_argument("--score", default="haa", help="buffer score (anr/cbs/haa/nss/cms)")
    p.add_argument("--eps", type=float, default=0.03, help="balance slack")
    p.add_argument("--buffer-size", type=int, default=None, help="Q_max (default: n/8)")
    p.add_argument("--batch-size", type=int, default=None, help="delta (default: n/32)")
    p.add_argument("--d-max", type=float, default=None, help="hub threshold (default: n/16)")
    p.add_argument("--gamma", type=float, default=1.5)
    p.add_argument("--wave", type=int, default=1, help="vectorized: eviction wave size")
    p.add_argument("--chunk", type=int, default=1, help="vectorized: arrival chunk size")
    p.add_argument("--queue-depth", type=int, default=4, help="pipelined: task queue bound")
    p.add_argument("--read-ahead", type=int, default=64, help="pipelined: read-ahead records")
    p.add_argument("--prefetch-batches", type=int, default=2,
                   help="stream prefetcher depth in batches (0 disables the "
                        "background reader thread)")
    p.add_argument("--workers", type=int, default=1, metavar="W",
                   help="shard the stream across W BuffCut workers "
                        "(contiguous id ranges; pair with --restream to "
                        "reconcile the shard seams)")
    p.add_argument("--load-sync-every", type=int, default=8, metavar="S",
                   help="sharded: committed batches between load-sync "
                        "barrier rounds per worker")
    p.add_argument("--shard-backend", default="thread",
                   choices=["thread", "process"],
                   help="sharded: worker threads (deterministic anchor) or "
                        "forked processes (multi-core scaling)")
    p.add_argument("--restream", type=int, default=0, metavar="N",
                   help="restreaming refinement passes (replays the stream "
                        "out-of-core on disk sources)")
    p.add_argument("--restream-order", default="stream",
                   choices=["stream", "priority"],
                   help="replay order for restream passes: contiguous stream "
                        "order or gain-prioritized δ-batches")
    p.add_argument("--checkpoint", metavar="PATH", default=None,
                   help="write crash-safe snapshots here (atomic; resume "
                        "with --resume PATH)")
    p.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                   help="committed batches between snapshots "
                        "(default 8 when --checkpoint is set)")
    p.add_argument("--resume", metavar="CKPT", default=None,
                   help="resume a checkpointed run bit-identically; config "
                        "and source come from the checkpoint (tuning flags "
                        "are ignored), SOURCE overrides the recorded one")
    p.add_argument("--materialize", action="store_true",
                   help="load a disk source into memory (required for "
                        "memory-only drivers on file sources)")
    p.add_argument("--stats", action="store_true",
                   help="collect per-batch stats (IER, evictions)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the full PartitionResult JSON here")
    p.set_defaults(cmd=_cmd_partition)


def _print_summary(res, json_path: "str | None") -> None:
    prov = res.provenance
    print(
        f"driver={prov['driver']} engine={prov['engine']} ordering={prov['ordering']} "
        f"source={prov['source']['kind']} n={prov['source']['n']} m={prov['source']['m']} "
        f"k={res.k} cut_ratio={res.cut_ratio:.4f} balance={res.balance:.3f} "
        f"runtime_s={prov['runtime_s']:.3f}"
    )
    if json_path:
        res.to_json(json_path)
        print(f"wrote {json_path}")


def _cmd_partition(args: argparse.Namespace) -> int:
    from repro.api import DriverConfig, partition, resolve_source, resume
    from repro.configs.buffcut_paper import scaled_config

    if args.resume:
        overrides = {}
        if args.checkpoint:
            overrides["checkpoint_path"] = args.checkpoint
        if args.checkpoint_every:
            overrides["checkpoint_every"] = args.checkpoint_every
        res = resume(args.resume, source=args.source, **overrides)
        _print_summary(res, args.json)
        return 0
    if args.source is None:
        raise ValueError("SOURCE is required unless --resume is given")
    if args.k is None:
        raise ValueError("-k is required unless --resume is given")
    src = resolve_source(args.source)
    if args.materialize:
        src.materialize()
    n = src.stream.n
    base = scaled_config(n, k=args.k, eps=args.eps)
    dc = DriverConfig.create(
        DriverConfig(buffcut=base),
        driver=args.driver,
        k=args.k,
        eps=args.eps,
        score=args.score,
        gamma=args.gamma,
        engine=args.engine,
        ordering=args.ordering,
        order_seed=args.order_seed,
        restream_passes=args.restream,
        restream_order=args.restream_order,
        wave=args.wave,
        chunk=args.chunk,
        queue_depth=args.queue_depth,
        read_ahead=args.read_ahead,
        prefetch_batches=args.prefetch_batches,
        workers=args.workers,
        load_sync_every=args.load_sync_every,
        shard_backend=args.shard_backend,
        collect_stats=args.stats,
        **{
            key: val
            for key, val in (
                ("buffer_size", args.buffer_size),
                ("batch_size", args.batch_size),
                ("d_max", args.d_max),
                ("checkpoint_path", args.checkpoint),
                ("checkpoint_every", args.checkpoint_every or None),
            )
            if val is not None
        },
    )
    res = partition(src, dc)
    _print_summary(res, args.json)
    return 0


def _add_serve_parser(sub: "argparse._SubParsersAction") -> None:
    p = sub.add_parser(
        "serve",
        help="partition a source, keep it resident, and drive a scripted "
             "update/lookup/refine workload",
        description="Partition SOURCE, promote the result into a resident "
                    "PartitionService (repro.serve), and replay a scripted "
                    "workload — a delta file (--delta-file) or a generated "
                    "churn spec (--workload gen:churn:updates=64,...) — "
                    "through a ServeSession, reporting per-verb latencies, "
                    "sustained rates, and the exactness check "
                    "(resident cut == edge_cut recompute).",
    )
    p.add_argument("source",
                   help="METIS text / packed binary path, or gen:<family>:... spec")
    p.add_argument("-k", type=int, required=True, help="number of blocks")
    p.add_argument("--driver", default="buffcut",
                   help="dynamic-capable registry driver "
                        "(see `python -m repro list` capability flags)")
    p.add_argument("--workload", default="gen:churn:",
                   help="churn spec: gen:churn:updates=64,ops=16,frac_del=0.25,"
                        "node_adds=0,lookup_every=4,lookup_size=256,"
                        "refine_every=8,seed=0 (defaults shown for omitted "
                        "fields)")
    p.add_argument("--delta-file", metavar="PATH", default=None,
                   help="scripted delta file (overrides --workload; see "
                        "repro.serve.workload for the line grammar)")
    p.add_argument("--eps", type=float, default=0.03, help="balance slack")
    p.add_argument("--score", default="haa", help="buffer score (anr/cbs/haa/nss/cms)")
    p.add_argument("--buffer-size", type=int, default=None, help="Q_max (default: n/8)")
    p.add_argument("--batch-size", type=int, default=None, help="delta (default: n/32)")
    p.add_argument("--queue-depth", type=int, default=256,
                   help="session request queue bound")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the serve report JSON here (default: stdout "
                        "summary only)")
    p.set_defaults(cmd=_cmd_serve)


def _cmd_serve(args: argparse.Namespace) -> int:
    import dataclasses
    import json

    from repro.api import DriverConfig, partition, resolve_source
    from repro.configs.buffcut_paper import scaled_config
    from repro.core.metrics import edge_cut
    from repro.serve import ChurnSpec, ServeSession, churn_ops, load_delta_file, run_workload

    src = resolve_source(args.source)
    src.materialize()  # the service keeps the graph resident
    base = scaled_config(src.stream.n, k=args.k, eps=args.eps)
    dc = DriverConfig.create(
        DriverConfig(buffcut=base),
        driver=args.driver, k=args.k, eps=args.eps, score=args.score,
        **{key: val for key, val in (("buffer_size", args.buffer_size),
                                     ("batch_size", args.batch_size))
           if val is not None},
    )
    res = partition(src, dc)
    service = res.into_service()
    if args.delta_file is not None:
        ops = load_delta_file(args.delta_file)
        workload_desc = {"kind": "delta_file", "path": args.delta_file}
    else:
        spec = ChurnSpec.parse(args.workload)
        ops = churn_ops(service.export_graph(), spec)
        workload_desc = {"kind": "churn", "spec": dataclasses.asdict(spec)}
    with ServeSession(service, queue_depth=args.queue_depth) as sess:
        summary = run_workload(sess, ops)
    cut_recompute = edge_cut(service.export_graph(), service.labels)
    report = {
        "provenance": {
            "driver": res.provenance["driver"],
            "source": res.provenance["source"],
            "k": res.k,
            "initial_cut": float(res.cut_weight),
            "workload": workload_desc,
            "ops": len(ops),
        },
        "workload": summary,
        "session": dict(sess.stats),
        "service": service.stats(),
        "exact": {
            "resident_cut": float(service.cut_weight),
            "recomputed_cut": float(cut_recompute),
            "match": bool(service.cut_weight == cut_recompute),
        },
    }
    if not report["exact"]["match"]:
        print(
            f"error: resident cut {service.cut_weight} != recomputed "
            f"{cut_recompute} after the workload — exactness invariant "
            "violated",
            file=sys.stderr,
        )
        return 1
    upd = summary["update"]
    lkp = summary["lookup"]
    print(
        f"serve driver={res.provenance['driver']} n={service.n} m={service.m} "
        f"k={service.k} ops={len(ops)} "
        f"cut={service.cut_weight:.0f} (exact) balance={service.balance:.3f} "
        f"updates_per_s={upd['updates_per_s']:.0f} "
        f"lookup_p50_ms={lkp['p50_ms']:.3f} lookup_p99_ms={lkp['p99_ms']:.3f}"
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, sort_keys=True, indent=1)
        print(f"wrote {args.json}")
    return 0


def _add_gen_parser(sub: "argparse._SubParsersAction") -> None:
    p = sub.add_parser(
        "gen",
        help="synthesize an instance family to disk",
        description="Generate FAMILY to -o PATH (packed binary by default; "
                    "grid/ring stream incrementally and never materialize).",
    )
    p.add_argument("family", help="rmat | rgg | rhg | grid | sbm | star | ring")
    p.add_argument("-o", "--out", required=True, help="output path")
    p.add_argument("--format", default="packed", choices=["packed", "metis"],
                   help="on-disk format")
    p.add_argument("--param", action="append", default=[], metavar="K=V",
                   help="generator parameter, repeatable (e.g. --param side=64)")
    p.set_defaults(cmd=_cmd_gen)


def _cmd_gen(args: argparse.Namespace) -> int:
    from repro.graphs.generators import generate_to_disk
    from repro.graphs.io import write_metis
    from repro.api.sources import GEN_PREFIX, GENERATORS, parse_generator_spec

    # one parser for generator params: the same spec syntax partition accepts
    spec = GEN_PREFIX + args.family
    if args.param:
        spec += ":" + ",".join(args.param)
    family, params = parse_generator_spec(spec)
    if args.format == "packed":
        n = generate_to_disk(family, args.out, **params)
    else:
        g = GENERATORS[family](**params)
        write_metis(g, args.out)
        n = g.n
    print(f"wrote {args.out} ({family}, n={n}, format={args.format})")
    return 0


def _cmd_list(args: argparse.Namespace) -> int:
    from repro.api import get_partitioner, list_partitioners

    for name in list_partitioners():
        spec = get_partitioner(name)
        mode = "streaming" if spec.streaming else "memory-only"
        caps = spec.capabilities()
        flags = ", ".join(
            label for label, on in (
                ("disk-stream", caps["disk_stream"]),
                ("checkpoint", caps["checkpoint"]),
                ("shard", caps["shard"]),
                ("dynamic", caps["dynamic"]),
            ) if on
        ) or "none"
        line = f"{name:14s} [{mode}]  caps: {flags}"
        if spec.aliases:
            line += f"  aliases: {', '.join(spec.aliases)}"
        print(line)
        if args.verbose and spec.description:
            print(f"    {spec.description}")
    return 0


def _cmd_analyze(args: argparse.Namespace) -> int:
    # the analyzer is stdlib-only; its exit code is the verb's exit code
    from repro.analysis.cli import run as analysis_run

    return analysis_run(args)


def _add_analyze_parser(sub) -> None:
    from repro.analysis.cli import add_arguments

    p = sub.add_parser(
        "analyze",
        help="run the repo invariant linter (docs/INVARIANTS.md)",
        description="AST-based invariant linter: determinism, concurrency "
        "and IO contracts (rules RPR001-RPR008).",
    )
    add_arguments(p)
    p.set_defaults(cmd=_cmd_analyze)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="BuffCut reproduction — unified partitioner front door.",
    )
    sub = ap.add_subparsers(dest="command", required=True)
    _add_partition_parser(sub)
    _add_serve_parser(sub)
    _add_gen_parser(sub)
    _add_analyze_parser(sub)
    p_list = sub.add_parser("list", help="list registered partitioners")
    p_list.add_argument("-v", "--verbose", action="store_true")
    p_list.set_defaults(cmd=_cmd_list)
    return ap


def main(argv: "list[str] | None" = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.cmd(args)
    except (ValueError, TypeError, KeyError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
