"""`PartitionResult` — the one return type of `repro.api.partition`.

Carries the labels, the driver's `StreamStats`, provenance (driver, engine,
ordering, source, config snapshot), and lazily computed quality metrics.
Metrics prefer the exact in-memory computation when the source graph is
resident, and fall back to the streaming-measured `StreamStats` fields
(`cut_weight`, `balance` — filled by every BuffCut driver, conformance-
pinned equal to the offline metrics) when the partition ran out-of-core,
so `cut_ratio`/`balance` work without ever holding the graph.

`to_json`/`from_json` round-trip everything except the graph handle; the
metrics computed at serialization time are stored so a deserialized result
still answers quality queries.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.graphs.csr import CSRGraph
from repro.core import metrics as _metrics
from repro.core.buffcut import StreamStats

RESULT_SCHEMA_VERSION = 1


@dataclasses.dataclass
class PartitionResult:
    labels: np.ndarray                  # node id -> block, int64, input numbering
    k: int
    stats: StreamStats | None
    provenance: dict
    graph: CSRGraph | None = dataclasses.field(default=None, repr=False)
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------ metrics
    @property
    def cut_weight(self) -> float:
        """Total weight of cut edges (exact, in-memory or streaming)."""
        if "cut_weight" not in self._cache:
            if self.graph is not None:
                self._cache["cut_weight"] = _metrics.edge_cut(self.graph, self.labels)
            elif self.stats is not None:
                self._cache["cut_weight"] = float(self.stats.cut_weight)
            else:
                raise ValueError(
                    "cut_weight unavailable: no resident graph and the driver "
                    "returned no StreamStats"
                )
        return self._cache["cut_weight"]

    @property
    def cut_ratio(self) -> float:
        if "cut_ratio" not in self._cache:
            if self.graph is not None:
                self._cache["cut_ratio"] = _metrics.cut_ratio(self.graph, self.labels)
            else:
                m_total = float(self.provenance.get("m_total", 0.0))
                self._cache["cut_ratio"] = (
                    self.cut_weight / m_total if m_total > 0 else 0.0
                )
        return self._cache["cut_ratio"]

    @property
    def balance(self) -> float:
        """max block load / (c(V)/k); 1.0 = perfectly balanced."""
        if "balance" not in self._cache:
            if self.graph is not None:
                self._cache["balance"] = _metrics.balance(self.graph, self.labels, self.k)
            elif self.stats is not None and self.stats.balance > 0:
                self._cache["balance"] = float(self.stats.balance)
            else:
                raise ValueError(
                    "balance unavailable: no resident graph and no streaming "
                    "balance in StreamStats"
                )
        return self._cache["balance"]

    @property
    def ier(self) -> float:
        """Mean internal-edge ratio over batches (needs collect_stats=True;
        0.0 when the driver did not track it)."""
        return self.stats.mean_ier if self.stats is not None else 0.0

    def metrics(self) -> dict:
        return {"cut_ratio": self.cut_ratio, "balance": self.balance, "ier": self.ier}

    # ------------------------------------------------------------- serving
    def into_service(self, source=None, **service_kwargs):
        """Promote this result into a resident `repro.serve.PartitionService`
        — the partition stays alive and accepts lookup/update/refine
        (DESIGN.md §14).

        Gated on the driver's ``supports_dynamic`` capability (the three
        BuffCut drivers); baselines raise the standard actionable error
        naming a capable driver.  The service needs the graph resident:
        `self.graph` when the source was in memory, otherwise it is
        re-resolved and materialized from the provenance origin (file path
        or ``gen:`` spec) — or pass `source` explicitly for one-shot
        streams.  The service's cut/loads are recomputed from that resident
        graph at construction (not handed over from `StreamStats`), so the
        exactness invariant ``service.cut_weight == edge_cut(...)`` holds
        by construction regardless of orderings or restream history.

        Extra keyword arguments (``buffer_cap``, ``refine_batch``,
        ``cache_bytes``) pass through to `PartitionService`.
        """
        from repro.api.registry import get_partitioner
        from repro.api.sources import resolve_source
        from repro.core.buffcut import BuffCutConfig
        from repro.serve.service import PartitionService

        driver = self.provenance.get("driver")
        if driver is not None:
            spec = get_partitioner(driver)
            if not spec.supports_dynamic:
                raise ValueError(
                    f"driver {spec.name!r} does not support dynamic serving; "
                    "dynamic-capable drivers: buffcut, buffcut-vec, "
                    "buffcut-pipe (see `python -m repro list` capability "
                    "flags)"
                )
        graph = self.graph
        if graph is None:
            origin = source
            if origin is None:
                origin = self.provenance.get("source", {}).get("origin")
            if origin is None:
                raise ValueError(
                    "into_service needs the graph resident: this result has "
                    "no attached graph and its provenance records no "
                    "re-resolvable source; pass source= explicitly"
                )
            graph = resolve_source(origin).materialize()
        cfg_dict = self.provenance.get("config", {}).get("buffcut")
        if cfg_dict is None:
            raise ValueError(
                "into_service needs the BuffCut config snapshot in "
                "provenance['config']['buffcut'] (results from "
                "repro.api.partition always carry it)"
            )
        cfg_dict = dict(cfg_dict)
        cfg_dict.pop("type", None)  # DriverConfig.to_dict discriminator
        cfg = BuffCutConfig.from_dict(cfg_dict)
        return PartitionService(graph, self.labels, cfg, **service_kwargs)

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {
            "version": RESULT_SCHEMA_VERSION,
            "k": int(self.k),
            "labels": self.labels.tolist(),
            "stats": self.stats.to_dict() if self.stats is not None else None,
            "provenance": self.provenance,
            "metrics": self.metrics(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PartitionResult":
        version = d.get("version", RESULT_SCHEMA_VERSION)
        if version != RESULT_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported PartitionResult schema version {version} "
                f"(this build reads version {RESULT_SCHEMA_VERSION})"
            )
        res = cls(
            labels=np.asarray(d["labels"], dtype=np.int64),
            k=int(d["k"]),
            stats=StreamStats.from_dict(d["stats"]) if d.get("stats") else None,
            provenance=d.get("provenance", {}),
        )
        m = d.get("metrics", {})
        res._cache.update(
            {key: float(m[key]) for key in ("cut_ratio", "balance") if key in m}
        )
        return res

    def to_json(self, path: "str | None" = None) -> str:
        text = json.dumps(self.to_dict(), sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, text_or_path: str) -> "PartitionResult":
        text = text_or_path
        if not text_or_path.lstrip().startswith("{"):
            with open(text_or_path) as f:
                text = f.read()
        return cls.from_dict(json.loads(text))
