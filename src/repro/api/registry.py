"""The partitioner registry — one `PartitionerSpec` per algorithm.

Every algorithm this repo implements registers here, and every future
scenario PR plugs in the same way: `register_partitioner` a spec whose
`run(source, config)` maps a `ResolvedSource` + `DriverConfig` to
`(labels, StreamStats | None)`.  `repro.api.partition`, the CLI, the
benchmarks and the placement service all dispatch through this table —
there is no other driver lookup in the tree.

Streaming specs (`streaming=True`) consume the `NodeStreamBase` protocol
and therefore partition straight from disk; memory-only specs call
`require_graph`, which raises the standard actionable `TypeError` when
handed a disk stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.buffcut import StreamStats, _buffcut_partition
from repro.core.cuttana import _cuttana_partition
from repro.core.fennel import _fennel_partition, _ldg_partition
from repro.core.heistream import _heistream_partition
from repro.core.pipeline import _buffcut_partition_pipelined
from repro.core.vector_stream import _buffcut_partition_vectorized
from repro.api.config import DriverConfig, as_cuttana
from repro.api.sources import ResolvedSource

RunFn = Callable[[ResolvedSource, DriverConfig], "tuple[np.ndarray, StreamStats | None]"]


@dataclasses.dataclass(frozen=True)
class PartitionerSpec:
    name: str                      # canonical registry key
    run: RunFn
    streaming: bool                # consumes NodeStreamBase (out-of-core OK)
    description: str = ""
    aliases: tuple = ()
    # run accepts ckpt=/resume= kwargs (core/checkpoint.py); the facade
    # refuses --checkpoint/--resume for specs that don't
    supports_checkpoint: bool = False
    # the facade may route this driver through the sharded multi-worker
    # pool (distributed/shard_driver.py) when DriverConfig.workers > 1
    supports_shard: bool = False
    # results from this driver can be promoted to a resident
    # `repro.serve.PartitionService` via `PartitionResult.into_service()`
    # (the driver maintains the exact cut/loads contract the service
    # inherits; see DESIGN.md §14)
    supports_dynamic: bool = False

    def capabilities(self) -> dict:
        """Per-algorithm capability flags, the discoverable form of every
        actionable capability error (`python -m repro list` prints these)."""
        return {
            "disk_stream": self.streaming,
            "checkpoint": self.supports_checkpoint,
            "shard": self.supports_shard,
            "dynamic": self.supports_dynamic,
        }


_REGISTRY: dict[str, PartitionerSpec] = {}
_ALIASES: dict[str, str] = {}


def register_partitioner(spec: PartitionerSpec, *, overwrite: bool = False) -> PartitionerSpec:
    """Add a partitioner to the registry (future scenario PRs start here)."""
    names = (spec.name, *spec.aliases)
    for name in names:
        taken = name in _REGISTRY or name in _ALIASES
        if taken and not overwrite:
            raise ValueError(
                f"partitioner name {name!r} is already registered; pass "
                "overwrite=True to replace it"
            )
    if overwrite:  # reclaim every name, whether it was canonical or an alias
        for name in names:
            _REGISTRY.pop(name, None)
            _ALIASES.pop(name, None)
    _REGISTRY[spec.name] = spec
    for alias in spec.aliases:
        _ALIASES[alias] = spec.name
    return spec


def get_partitioner(name: str) -> PartitionerSpec:
    key = _ALIASES.get(name, name)
    spec = _REGISTRY.get(key)
    if spec is None:
        raise KeyError(
            f"unknown partitioner {name!r}: registered names are "
            f"{list_partitioners()} (aliases: {sorted(_ALIASES)})"
        )
    return spec


def list_partitioners() -> list[str]:
    """Canonical registry names, registration order."""
    return list(_REGISTRY)


# --------------------------------------------------------------------------
# built-in registrations — the paper's drivers + the baselines it compares to
# --------------------------------------------------------------------------


register_partitioner(PartitionerSpec(
    name="buffcut",
    aliases=("sequential",),
    streaming=True,
    description="BuffCut sequential driver (paper Alg. 1): prioritized "
                "buffer + batch-wise multilevel.",
    supports_checkpoint=True,
    supports_shard=True,
    supports_dynamic=True,
    run=lambda src, dc, **kw: _buffcut_partition(
        src.stream, dc.buffcut,
        prefetch_batches=dc.pipeline.prefetch_batches, **kw,
    ),
))

register_partitioner(PartitionerSpec(
    name="buffcut-vec",
    aliases=("vectorized",),
    streaming=True,
    description="Vectorized BuffCut: dense score vectors + top-wave "
                "eviction (TPU adaptation; wave=1,chunk=1 is bit-exact).",
    supports_checkpoint=True,
    supports_dynamic=True,
    run=lambda src, dc, **kw: _buffcut_partition_vectorized(
        src.stream, dc.buffcut, dc.vectorized,
        prefetch_batches=dc.pipeline.prefetch_batches, **kw,
    ),
))

register_partitioner(PartitionerSpec(
    name="buffcut-pipe",
    aliases=("pipelined", "buffcut-par"),
    streaming=True,
    description="Pipelined BuffCut (paper §3.5): reader / PQ handler / "
                "partition worker threads.",
    supports_checkpoint=True,
    supports_dynamic=True,
    run=lambda src, dc, **kw: _buffcut_partition_pipelined(
        src.stream, dc.buffcut, dc.pipeline, **kw
    ),
))

register_partitioner(PartitionerSpec(
    name="heistream",
    streaming=False,
    description="HeiStream baseline [Faraj & Schulz]: contiguous batches, "
                "same multilevel scheme (memory-only).",
    run=lambda src, dc: _heistream_partition(src.require_graph("heistream"), dc.buffcut),
))

register_partitioner(PartitionerSpec(
    name="cuttana",
    streaming=False,
    description="Cuttana baseline [Hajidehi et al.]: CBS buffer + "
                "sequential Fennel eviction + sub-partition trades "
                "(memory-only).",
    run=lambda src, dc: _cuttana_partition(
        src.require_graph("cuttana"), as_cuttana(dc.buffcut)
    ),
))

register_partitioner(PartitionerSpec(
    name="fennel",
    streaming=False,
    description="Fennel one-pass baseline [Tsourakakis et al.] (memory-only).",
    run=lambda src, dc: (
        _fennel_partition(
            src.require_graph("fennel"),
            dc.buffcut.k, dc.buffcut.eps, dc.buffcut.gamma,
        ),
        None,
    ),
))

register_partitioner(PartitionerSpec(
    name="ldg",
    streaming=False,
    description="Linear Deterministic Greedy baseline [Stanton & Kliot] "
                "(memory-only).",
    run=lambda src, dc: (
        _ldg_partition(src.require_graph("ldg"), dc.buffcut.k, dc.buffcut.eps),
        None,
    ),
))
