"""`repro.api` — the one front door to every partitioner in this repo.

    from repro.api import partition

    res = partition("graph.bcsr", k=16, driver="buffcut")        # flat kwargs
    res = partition(g, DriverConfig(driver="cuttana", ...))      # full config
    res.cut_ratio, res.balance, res.ier                          # lazy metrics
    res.to_json("out.json")                                      # round-trips

Sources: `CSRGraph`, any `NodeStreamBase`, a path to METIS text or packed
binary (streamed out-of-core), or a generator spec like
``gen:grid:side=64``.  Drivers: everything in `list_partitioners()`
(registry.py) — streaming drivers partition straight from disk, memory-only
baselines raise the standard actionable `TypeError` on disk streams.
Orderings are realized faithfully to the paper's protocol: in memory via
`apply_order`, or on disk via the permute/shard pass so the partitioning
path stays out-of-core; labels always come back in the *input* numbering.

The legacy per-driver functions remain importable but are deprecation
shims over this layer (bit-identity pinned in tests/test_api.py).
CLI twin: ``python -m repro partition <source> -k 16 --driver pipelined``.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.graphs.orderings import apply_order, bfs_order, konect_order
from repro.graphs.stream import NodeStream
from repro.graphs.stream_io import DiskNodeStream, permute_to_disk
from repro.core.buffcut import BuffCutConfig, StreamStats
from repro.core.checkpoint import CheckpointError, Checkpointer, load_checkpoint
from repro.core.restream import restream_refine as _restream_refine
from repro.distributed.shard_driver import shard_partition as _shard_partition
from repro.api.config import (
    ORDERINGS,
    CuttanaConfig,
    DriverConfig,
    MultilevelConfig,
    PipelineConfig,
    VectorizedConfig,
)
from repro.api.registry import (
    PartitionerSpec,
    get_partitioner,
    list_partitioners,
    register_partitioner,
)
from repro.api.result import PartitionResult
from repro.api.sources import ResolvedSource, resolve_source

__all__ = [
    "partition",
    "resume",
    "CheckpointError",
    "PartitionResult",
    "PartitionerSpec",
    "register_partitioner",
    "list_partitioners",
    "get_partitioner",
    "resolve_source",
    "ResolvedSource",
    "DriverConfig",
    "BuffCutConfig",
    "CuttanaConfig",
    "MultilevelConfig",
    "VectorizedConfig",
    "PipelineConfig",
    "ORDERINGS",
]


def _coerce_config(config, overrides: dict) -> DriverConfig:
    if config is None:
        return DriverConfig.create(**overrides)
    if isinstance(config, DriverConfig):
        return DriverConfig.create(config, **overrides) if overrides else config
    if isinstance(config, BuffCutConfig):  # includes CuttanaConfig
        return DriverConfig.create(DriverConfig(buffcut=config), **overrides)
    raise TypeError(
        f"config must be a DriverConfig or BuffCutConfig, got {type(config).__name__}"
    )


def _compute_perm(src: ResolvedSource, dc: DriverConfig) -> np.ndarray:
    if dc.ordering == "random":
        # identical to graphs.orderings.random_order, but needs only n —
        # disk sources stay out-of-core
        return np.random.default_rng(dc.order_seed).permutation(src.stream.n).astype(np.int64)
    g = src.graph if src.graph is not None else src.materialize()
    return bfs_order(g) if dc.ordering == "bfs" else konect_order(g, seed=dc.order_seed)


def _realize_ordering(
    src: ResolvedSource, dc: DriverConfig
) -> "tuple[ResolvedSource, np.ndarray | None, tempfile.TemporaryDirectory | None]":
    """Permute the source so streaming it reproduces `dc.ordering`.

    In-memory sources relabel via `apply_order`; disk sources go through the
    on-disk permute/shard pass (bit-identical, conformance-pinned) so the
    partitioning path never materializes the graph.  BFS/KONECT orderings
    need the structure to compute the permutation, so they materialize disk
    sources first; `random` does not.
    """
    if dc.ordering == "natural":
        return src, None, None
    perm = _compute_perm(src, dc)
    if src.graph is None and src.path is None:
        # foreign stream with no file behind it: the only way to reorder it
        src.materialize()
    if src.graph is not None:
        g2 = apply_order(src.graph, perm)
        return (
            ResolvedSource(NodeStream(g2), g2, src.kind, src.origin),
            perm,
            None,
        )
    tmp = tempfile.TemporaryDirectory(prefix="repro-ordering-")
    out = os.path.join(tmp.name, "ordered.bcsr")
    # preserve the source's tuned read-ahead window (memory-bound contract)
    chunk = getattr(src.stream, "io_chunk_bytes", None)
    kw = {} if chunk is None else {"io_chunk_bytes": chunk}
    permute_to_disk(src.path, perm, out, **kw)
    return (
        ResolvedSource(DiskNodeStream(out, **kw), None, src.kind, src.origin, path=out),
        perm,
        tmp,
    )


def partition(
    source,
    config: "DriverConfig | BuffCutConfig | None" = None,
    *,
    _resume_state: "dict | None" = None,
    **overrides,
) -> PartitionResult:
    """Partition `source` and return a `PartitionResult`.

    `config` is a `DriverConfig` (or a bare `BuffCutConfig`, wrapped);
    flat keyword overrides (``k=16, driver="pipelined", engine="jax",
    ordering="bfs", restream_passes=1, ...``) are routed by
    `DriverConfig.create`.  Labels are indexed by the input's node ids even
    when an ordering permutes the stream.

    With ``checkpoint_path`` set (``checkpoint_every`` batches per snapshot,
    default 8), the run is crash-safe: `repro.api.resume` — or ``python -m
    repro partition --resume <ckpt>`` — reopens the stream at the
    checkpointed byte offset and continues bit-identically (DESIGN.md §11).
    `_resume_state` is that internal handoff; use `resume()`.
    """
    dc = _coerce_config(config, overrides)
    spec = get_partitioner(dc.driver)
    if dc.workers > 1 and not spec.supports_shard:
        raise ValueError(
            f"driver {spec.name!r} does not support sharded multi-worker "
            "runs; shard-capable drivers: buffcut (or run workers=1)"
        )
    src = resolve_source(source)
    ckpt = None
    if dc.checkpoint_path:
        if not spec.supports_checkpoint:
            raise ValueError(
                f"driver {spec.name!r} does not support checkpointing; "
                "checkpoint-capable drivers: "
                "buffcut, buffcut-vec, buffcut-pipe"
            )
        ckpt = Checkpointer(dc.checkpoint_path, dc.checkpoint_every)
        # envelope merged into every snapshot so resume() can rebuild the
        # run from the file alone (in-memory sources can't be re-resolved;
        # resume() then requires an explicit source)
        source_spec = src.path if src.path is not None else (
            src.origin if src.kind == "generated" else None
        )
        ckpt.extra = {"api": {
            "driver_config_json": dc.to_json(),
            "source_spec": source_spec,
        }}
    rs = _resume_state
    if rs is not None and ckpt is None:
        raise ValueError(
            "resuming needs checkpointing enabled: set checkpoint_path "
            "(resume() carries it over from the checkpoint automatically)"
        )
    driver_resume = rs if rs is not None and rs.get("kind") != "restream" else None
    restream_resume = rs if rs is not None and rs.get("kind") == "restream" else None
    if restream_resume is not None and dc.restream_passes == 0:
        raise CheckpointError(
            "checkpoint was written during a restream pass but the resuming "
            "config has restream_passes=0"
        )
    run_src, perm, tmp = _realize_ordering(src, dc)
    if (
        (dc.restream_passes > 0 or dc.workers > 1)
        and run_src.graph is None
        and not isinstance(run_src.stream, DiskNodeStream)
    ):
        # restream and the shard split both replay the stream; a foreign
        # stream with no file behind it is not replayable, so load it up
        # front (before the first pass exhausts it).  NodeStream /
        # DiskNodeStream replay natively.
        g = run_src.materialize()
        run_src = ResolvedSource(NodeStream(g), g, run_src.kind, run_src.origin)
    t0 = time.perf_counter()
    rinfo = None
    shard_info = None
    try:
        if restream_resume is not None:
            # the driver phase finished before the checkpoint was written:
            # its labels and stats ride in the snapshot, skip straight to
            # the restream phase
            env = restream_resume.get("api") or {}
            sd = env.get("driver_stats")
            stats = StreamStats.from_dict(sd) if sd else None
            labels = np.asarray(restream_resume["block"], dtype=np.int64).copy()
        elif ckpt is not None:
            labels, stats = spec.run(run_src, dc, ckpt=ckpt, resume=driver_resume)
        elif dc.workers > 1:
            # sharded multi-worker pass (distributed/shard_driver.py); the
            # restream below then reconciles the shard seams from the exact
            # merged cut + loads the pool hands back
            labels, stats, shard_info = _shard_partition(
                run_src.stream,
                dc.buffcut,
                workers=dc.workers,
                load_sync_every=dc.load_sync_every,
                backend=dc.shard_backend,
                prefetch_batches=dc.pipeline.prefetch_batches,
            )
        else:
            labels, stats = spec.run(run_src, dc)
        if dc.restream_passes > 0:
            # streaming drivers hand over their exact accumulated cut and
            # final block loads (skipping the restream prelude replay); the
            # memory-only baselines don't maintain them, so the prelude
            # computes both
            seeded = stats is not None and spec.streaming
            ckpt_pre = ckpt.written if ckpt is not None else 0
            if ckpt is not None:
                if stats is not None:
                    ckpt.extra["api"]["driver_stats"] = stats.to_dict()
                ckpt.reset()  # restream batch counters restart from zero
            labels, rinfo = _restream_refine(
                run_src.graph if run_src.graph is not None else run_src.stream,
                labels,
                dc.buffcut,
                dc.restream_passes,
                order=dc.restream_order,
                prefetch_batches=dc.pipeline.prefetch_batches,
                initial_cut=stats.cut_weight if seeded else None,
                initial_loads=(
                    np.asarray(stats.block_loads, dtype=np.float64)
                    if seeded and stats.block_loads else None
                ),
                ckpt=ckpt,
                resume=restream_resume,
            )
            if ckpt is not None and stats is not None:
                # restream-phase snapshots land in the same stats counter
                # the driver phase already started
                stats.checkpoints_written += ckpt.written - ckpt_pre
    finally:
        if tmp is not None:
            tmp.cleanup()
    runtime_s = time.perf_counter() - t0
    if stats is not None and rinfo is not None:
        # refresh: the labels were refined, so the streamed quality fields
        # must describe the refined assignment, not pass 1's
        stats.cut_weight = rinfo.cut_weight
        stats.balance = rinfo.balance
        stats.peak_resident_bytes = max(
            stats.peak_resident_bytes, rinfo.peak_resident_bytes
        )
        stats.stream_bytes_read += rinfo.stream_bytes_read
    if perm is not None:  # map stream-position labels back to input node ids
        orig = np.empty_like(labels)
        orig[perm] = labels
        labels = orig
    provenance = {
        "driver": spec.name,
        "engine": dc.buffcut.ml.engine,
        "ordering": dc.ordering,
        "order_seed": dc.order_seed,
        "restream_passes": dc.restream_passes,
        "restream_order": dc.restream_order,
        "source": {
            "kind": src.kind,
            "origin": src.origin,
            "n": int(src.stream.n),
            "m": int(src.stream.m),
        },
        "n_total": float(run_src.stream.n_total),
        "m_total": float(run_src.stream.m_total),
        "runtime_s": runtime_s,
        "config": dc.to_dict(),
    }
    if shard_info is not None:
        # per-worker stats, sync rounds, ranges, pre-reconcile cut split;
        # the post-reconcile trace is provenance["restream"]["passes"]
        provenance["sharded"] = shard_info
    if rinfo is not None:
        # pass-by-pass provenance: replay order, batches, moves, cut trace
        provenance["restream"] = rinfo.to_dict()
    return PartitionResult(
        labels=labels,
        k=dc.buffcut.k,
        stats=stats,
        provenance=provenance,
        graph=src.graph,
    )


def resume(
    checkpoint_path: str,
    source=None,
    config: "DriverConfig | BuffCutConfig | None" = None,
    **overrides,
) -> PartitionResult:
    """Resume a checkpointed `partition` run and carry it to completion.

    Loads the snapshot (magic/version/CRC verified — a torn or corrupt file
    raises `CheckpointError`, never a wrong partition), rebuilds the
    `DriverConfig` recorded in it (flat `overrides` still apply; anything
    that changes the labels fails the resume identity check loudly),
    re-resolves the source — from the recorded path / generator spec, or
    from an explicit `source` when the original was an in-memory object —
    and continues bit-identically from the recorded stream offset.
    Snapshots keep being written to the same file unless overridden with
    ``checkpoint_path=...``.
    """
    state = load_checkpoint(checkpoint_path)
    env = state.get("api") or {}
    if config is not None:
        dc = _coerce_config(config, overrides)
    elif env.get("driver_config_json"):
        dc = DriverConfig.from_json(env["driver_config_json"])
        if overrides:
            dc = DriverConfig.create(dc, **overrides)
    else:
        raise CheckpointError(
            f"checkpoint {checkpoint_path!r} has no recorded config "
            "(written outside repro.api?); pass config= explicitly"
        )
    if dc.checkpoint_path != checkpoint_path and "checkpoint_path" not in overrides:
        dc = dataclasses.replace(dc, checkpoint_path=checkpoint_path)
    if source is None:
        source = env.get("source_spec")
        if source is None:
            raise CheckpointError(
                "the original run's source was an in-memory object the "
                "checkpoint cannot re-resolve; pass source= explicitly"
            )
    return partition(source, dc, _resume_state=state)
