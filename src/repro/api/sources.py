"""Source resolution — every way a graph can reach the partitioner.

`resolve_source` accepts the five source kinds the API contract names
(DESIGN.md §9) and normalizes them into a `ResolvedSource` that always
carries a `NodeStreamBase` (what the streaming drivers consume) and, when
the graph genuinely lives in memory, the `CSRGraph` (what the memory-only
baselines and the restream post-pass need):

  * `CSRGraph`                  — kind "graph"
  * `NodeStreamBase`            — kind "stream" (an in-memory `NodeStream`
                                  exposes its wrapped graph; a disk stream
                                  does not)
  * path to METIS text          — kind "metis",  streamed via DiskNodeStream
  * path to packed binary       — kind "packed", streamed via DiskNodeStream
  * generator spec string       — kind "generated", e.g. "gen:grid:side=64"
                                  or "gen:rmat:n=4096,avg_degree=8,seed=11"
                                  (families: {families})

Memory-only algorithms never silently materialize a disk stream: they call
`require_graph`, which raises the actionable `TypeError` the core guards
standardize.  `materialize()` is the explicit opt-in that loads a disk
source (or assembles any stream) into a CSRGraph.
"""
from __future__ import annotations

import dataclasses
import os

from repro.graphs.csr import CSRGraph
from repro.graphs.generators import (
    grid_mesh_graph,
    rgg_graph,
    rhg_like_graph,
    ring_graph,
    rmat_graph,
    sbm_graph,
    star_graph,
)
from repro.graphs.io import read_metis
from repro.graphs.stream import NodeStream, NodeStreamBase
from repro.graphs.stream_io import MAGIC, DiskNodeStream, materialize_records, read_packed
from repro.core._deprecation import require_csr

GEN_PREFIX = "gen:"

GENERATORS = {
    "rmat": rmat_graph,
    "rgg": rgg_graph,
    "rhg": rhg_like_graph,
    "grid": grid_mesh_graph,
    "sbm": sbm_graph,
    "star": star_graph,
    "ring": ring_graph,
}

if __doc__:  # stripped under -OO
    __doc__ = __doc__.format(families=", ".join(sorted(GENERATORS)))


def _parse_value(tok: str):
    for cast in (int, float):
        try:
            return cast(tok)
        except ValueError:
            pass
    if tok.lower() in ("true", "false"):
        return tok.lower() == "true"
    return tok


def parse_generator_spec(spec: str) -> tuple[str, dict]:
    """``gen:<family>[:k=v[,k=v...]]`` -> (family, params)."""
    body = spec[len(GEN_PREFIX):]
    family, _, params_s = body.partition(":")
    if family not in GENERATORS:
        raise ValueError(
            f"unknown generator family {family!r} in source spec {spec!r}: "
            f"known families are {sorted(GENERATORS)}"
        )
    params: dict = {}
    for item in filter(None, params_s.split(",")):
        key, sep, val = item.partition("=")
        if not sep:
            raise ValueError(
                f"malformed generator param {item!r} in {spec!r} (want key=value)"
            )
        params[key] = _parse_value(val)
    return family, params


def build_generated(spec: str) -> CSRGraph:
    family, params = parse_generator_spec(spec)
    try:
        return GENERATORS[family](**params)
    except TypeError as e:
        raise ValueError(f"bad params for generator {family!r}: {e}") from None


@dataclasses.dataclass
class ResolvedSource:
    stream: NodeStreamBase
    graph: CSRGraph | None
    kind: str            # "graph" | "stream" | "metis" | "packed" | "generated"
    origin: str          # provenance string (path / spec / shape)
    path: str | None = None

    def require_graph(self, algo: str) -> CSRGraph:
        """The in-memory graph, or the standard memory-only TypeError."""
        if self.graph is not None:
            return self.graph
        return require_csr(self.stream, algo)

    def materialize(self) -> CSRGraph:
        """Explicitly load this source into memory (opt-in: defeats the
        out-of-core property for disk sources)."""
        if self.graph is None:
            if self.path is not None:
                with open(self.path, "rb") as f:  # sniff the on-disk format
                    packed = f.read(4) == MAGIC
                self.graph = read_packed(self.path) if packed else read_metis(self.path)
            else:  # a foreign stream implementation: assemble its records
                self.graph = materialize_records(
                    self.stream.n, (rec[1:] for rec in self.stream)
                )
        return self.graph


def resolve_source(
    source: "CSRGraph | NodeStreamBase | ResolvedSource | str | os.PathLike",
    *,
    io_chunk_bytes: int | None = None,
) -> ResolvedSource:
    if isinstance(source, ResolvedSource):
        return source
    if isinstance(source, CSRGraph):
        return ResolvedSource(
            stream=NodeStream(source),
            graph=source,
            kind="graph",
            origin=f"CSRGraph(n={source.n}, m={source.m})",
        )
    if isinstance(source, NodeStream):
        return ResolvedSource(
            stream=source,
            graph=source._g,
            kind="stream",
            origin=f"NodeStream(n={source.n}, m={source.m})",
        )
    if isinstance(source, NodeStreamBase):
        path = getattr(source, "path", None)
        return ResolvedSource(
            stream=source,
            graph=None,
            kind="stream",
            origin=f"{type(source).__name__}(n={source.n}, m={source.m})",
            path=path,
        )
    if isinstance(source, (str, os.PathLike)):
        spec = os.fspath(source)
        if spec.startswith(GEN_PREFIX):
            g = build_generated(spec)
            return ResolvedSource(
                stream=NodeStream(g), graph=g, kind="generated", origin=spec
            )
        if not os.path.exists(spec):
            raise FileNotFoundError(
                f"graph source {spec!r} does not exist (expected a METIS text "
                f"or packed-binary file, or a '{GEN_PREFIX}<family>:...' spec)"
            )
        kw = {} if io_chunk_bytes is None else {"io_chunk_bytes": io_chunk_bytes}
        stream = DiskNodeStream(spec, **kw)
        return ResolvedSource(
            stream=stream,
            graph=None,
            kind="packed" if stream._packed else "metis",
            origin=spec,
            path=spec,
        )
    raise TypeError(
        f"cannot resolve a graph source from {type(source).__name__}: pass a "
        "CSRGraph, a NodeStream, a path to a METIS/packed file, or a "
        f"'{GEN_PREFIX}<family>:...' generator spec"
    )
