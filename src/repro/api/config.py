"""`DriverConfig` — the one config object `repro.api.partition` consumes.

Composes the existing config dataclasses instead of re-inventing them:
`BuffCutConfig` (algorithm parameters, including the nested
`MultilevelConfig`), `VectorizedConfig` (the vectorized driver's former
loose kwargs) and `PipelineConfig` (the pipelined driver's), plus the
facade-level knobs: which driver, which stream ordering, and the
restreaming post-pass count + replay order (`restream_passes` /
`restream_order`, core/restream.py — streams out-of-core on disk sources).

`DriverConfig.create` is the flat-kwarg builder the CLI and the
`partition(source, k=..., driver=...)` convenience path share: every key is
routed to the dataclass that owns it, unknown keys fail loudly with the
full routing table.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core.buffcut import BuffCutConfig
from repro.core.cuttana import CuttanaConfig
from repro.core.multilevel import MultilevelConfig
from repro.core.pipeline import PipelineConfig
from repro.core.restream import RESTREAM_ORDERS
from repro.core.vector_stream import VectorizedConfig
from repro.distributed.shard_driver import SHARD_BACKENDS

ORDERINGS = ("natural", "random", "bfs", "konect")

# flat-kwarg routing table for DriverConfig.create (CLI + partition(**kw))
_TOP_KEYS = (
    "driver", "ordering", "order_seed", "restream_passes", "restream_order",
    "checkpoint_path", "checkpoint_every",
    "workers", "load_sync_every", "shard_backend",
)
_BUFFCUT_KEYS = (
    "k", "eps", "buffer_size", "batch_size", "d_max", "score",
    "disc_factor", "gamma", "collect_stats",
)
_ML_KEYS = (
    "coarsen_target", "max_levels", "lp_iters", "refine_rounds",
    "min_shrink", "seed", "agg_autotune",
)  # plus "engine", routed to ml below
_VEC_KEYS = ("wave", "chunk")  # plus "vec_engine" -> VectorizedConfig.engine
_PIPE_KEYS = ("queue_depth", "read_ahead", "prefetch_batches")
_CUTTANA_KEYS = ("subpart_ratio", "refine_passes")


def _default_buffcut() -> BuffCutConfig:
    return BuffCutConfig(k=16)


def as_cuttana(cfg: BuffCutConfig) -> CuttanaConfig:
    """Upgrade a BuffCutConfig to a CuttanaConfig (default phase-2 knobs),
    passing an existing CuttanaConfig through untouched."""
    if isinstance(cfg, CuttanaConfig):
        return cfg
    return CuttanaConfig(
        **{f.name: getattr(cfg, f.name) for f in dataclasses.fields(BuffCutConfig)}
    )


@dataclasses.dataclass
class DriverConfig:
    driver: str = "buffcut"
    buffcut: BuffCutConfig = dataclasses.field(default_factory=_default_buffcut)
    vectorized: VectorizedConfig = dataclasses.field(default_factory=VectorizedConfig)
    pipeline: PipelineConfig = dataclasses.field(default_factory=PipelineConfig)
    restream_passes: int = 0
    restream_order: str = "stream"
    ordering: str = "natural"
    order_seed: int = 0
    # crash-safe checkpointing (core/checkpoint.py, DESIGN.md §11): snapshot
    # to `checkpoint_path` every `checkpoint_every` committed batches
    checkpoint_path: "str | None" = None
    checkpoint_every: int = 0
    # sharded multi-worker partitioning (distributed/shard_driver.py,
    # DESIGN.md §13): W contiguous id-range shards, one driver each, loads
    # synced every `load_sync_every` committed batches per worker
    workers: int = 1
    load_sync_every: int = 8
    shard_backend: str = "thread"

    def __post_init__(self) -> None:
        if self.ordering not in ORDERINGS:
            raise ValueError(
                f"unknown ordering {self.ordering!r}: pick one of {ORDERINGS}"
            )
        if self.restream_passes < 0:
            raise ValueError(
                f"restream_passes must be >= 0, got {self.restream_passes}"
            )
        if self.restream_order not in RESTREAM_ORDERS:
            raise ValueError(
                f"unknown restream_order {self.restream_order!r}: pick one of "
                f"{RESTREAM_ORDERS}"
            )
        if self.checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {self.checkpoint_every}"
            )
        if self.checkpoint_every > 0 and not self.checkpoint_path:
            raise ValueError(
                "checkpoint_every > 0 needs a checkpoint_path to write to"
            )
        if self.checkpoint_path and self.checkpoint_every == 0:
            # path alone opts in; default cadence (EXPERIMENTS.md: <3%
            # overhead at every=8 on the hot-path grid)
            self.checkpoint_every = 8
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.load_sync_every < 1:
            raise ValueError(
                f"load_sync_every must be >= 1, got {self.load_sync_every}"
            )
        if self.shard_backend not in SHARD_BACKENDS:
            raise ValueError(
                f"unknown shard_backend {self.shard_backend!r}: pick one of "
                f"{SHARD_BACKENDS}"
            )
        if self.workers > 1 and self.checkpoint_path:
            # a sharded run has W independent stream positions plus barrier
            # state — a single resume token cannot represent it, and a stale
            # single-worker snapshot must never silently resume a sharded run
            raise ValueError(
                "checkpointing is not supported with workers > 1: a sharded "
                "run has one stream position per worker and cannot resume "
                "from a single token; drop checkpoint_path or run workers=1"
            )

    # ------------------------------------------------------- flat builder
    @classmethod
    def create(cls, base: "DriverConfig | None" = None, **kw) -> "DriverConfig":
        """Build (or override) a DriverConfig from flat kwargs.

        ``engine`` routes to the multilevel engine (``ml.engine``);
        ``vec_engine`` to the vectorized buffer engine.  Cuttana's
        ``subpart_ratio``/``refine_passes`` upgrade the algorithm config to
        a `CuttanaConfig`.
        """
        top: dict = {}
        bc: dict = {}
        ml: dict = {}
        vec: dict = {}
        pipe: dict = {}
        cut: dict = {}
        for key, val in kw.items():
            if key in _TOP_KEYS:
                top[key] = val
            elif key in _BUFFCUT_KEYS:
                bc[key] = val
            elif key in _ML_KEYS:
                ml[key] = val
            elif key == "engine":
                ml["engine"] = val
            elif key in _VEC_KEYS:
                vec[key] = val
            elif key == "vec_engine":
                vec["engine"] = val
            elif key in _PIPE_KEYS:
                pipe[key] = val
            elif key in _CUTTANA_KEYS:
                cut[key] = val
            else:
                raise TypeError(
                    f"unknown partition option {key!r}; valid options: "
                    f"{_TOP_KEYS + _BUFFCUT_KEYS + ('engine',) + _ML_KEYS} "
                    f"(multilevel), {_VEC_KEYS + ('vec_engine',)} (vectorized), "
                    f"{_PIPE_KEYS} (pipelined), {_CUTTANA_KEYS} (cuttana)"
                )
        base = base if base is not None else cls()
        buffcut = base.buffcut
        if ml:
            bc["ml"] = dataclasses.replace(buffcut.ml, **ml)
        if bool(cut) or top.get("driver", base.driver) == "cuttana":
            buffcut = as_cuttana(buffcut)
        if bc or cut:
            buffcut = dataclasses.replace(buffcut, **bc, **cut)
        return dataclasses.replace(
            base,
            buffcut=buffcut,
            vectorized=dataclasses.replace(base.vectorized, **vec),
            pipeline=dataclasses.replace(base.pipeline, **pipe),
            **top,
        )

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        bc = self.buffcut.to_dict()
        bc["type"] = "cuttana" if isinstance(self.buffcut, CuttanaConfig) else "buffcut"
        return {
            "driver": self.driver,
            "buffcut": bc,
            "vectorized": self.vectorized.to_dict(),
            "pipeline": self.pipeline.to_dict(),
            "restream_passes": self.restream_passes,
            "restream_order": self.restream_order,
            "ordering": self.ordering,
            "order_seed": self.order_seed,
            "checkpoint_path": self.checkpoint_path,
            "checkpoint_every": self.checkpoint_every,
            "workers": self.workers,
            "load_sync_every": self.load_sync_every,
            "shard_backend": self.shard_backend,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DriverConfig":
        bc = dict(d["buffcut"])
        bc_cls = CuttanaConfig if bc.pop("type", "buffcut") == "cuttana" else BuffCutConfig
        return cls(
            driver=d.get("driver", "buffcut"),
            buffcut=bc_cls.from_dict(bc),
            vectorized=VectorizedConfig.from_dict(d.get("vectorized", {})),
            pipeline=PipelineConfig.from_dict(d.get("pipeline", {})),
            restream_passes=d.get("restream_passes", 0),
            restream_order=d.get("restream_order", "stream"),
            ordering=d.get("ordering", "natural"),
            order_seed=d.get("order_seed", 0),
            checkpoint_path=d.get("checkpoint_path"),
            checkpoint_every=d.get("checkpoint_every", 0),
            workers=d.get("workers", 1),
            load_sync_every=d.get("load_sync_every", 8),
            shard_backend=d.get("shard_backend", "thread"),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "DriverConfig":
        return cls.from_dict(json.loads(s))


__all__ = [
    "DriverConfig",
    "as_cuttana",
    "BuffCutConfig",
    "CuttanaConfig",
    "MultilevelConfig",
    "VectorizedConfig",
    "PipelineConfig",
    "ORDERINGS",
]
